//! Accel-GCN: reproduction of "Accel-GCN: High-Performance GPU Accelerator
//! Design for Graph Convolution Networks" (ICCAD 2023) as a three-layer
//! Rust + JAX + Pallas system.
//!
//! Layer map (see DESIGN.md):
//! * [`graph`] — graph substrate: CSR containers, synthetic generators for
//!   the 18 benchmark graphs, IO, O(n) degree sorting.
//! * [`partition`] — the paper's preprocessing contribution: partition
//!   pattern table (Alg. 1), block-level partitioning (Alg. 2), int4
//!   metadata, the warp-level (GNNAdvisor-style) baseline, and the BELL
//!   bucket layout consumed by the Pallas kernel.
//! * [`spmm`] — exact CPU executors for every schedule (numeric ground
//!   truth for the partitioners).
//! * [`pipeline`] — the unified SpMM execution pipeline: cached
//!   [`pipeline::SpmmPlan`]s (degree sort + both partitions, built once
//!   per graph), the [`pipeline::Executor`] trait over every schedule,
//!   and the thread-pool-parallel block-level executor. Every consumer —
//!   binary, bench harness, simulator, coordinator — builds schedules
//!   through this layer.
//! * [`sim`] — GPU microarchitecture simulator reproducing the paper's
//!   evaluation (warps, coalescing, shared memory, SM scheduling);
//!   simulates plans prepared by [`pipeline`].
//! * [`coordinator`] — PJRT serving engine: request router, shape-bucket
//!   batcher, worker pool (requires compiled artifacts).
//! * [`delta`] — dynamic graphs: batched edge updates over an immutable
//!   base CSR ([`delta::DeltaGraph`]) and incremental plan maintenance
//!   ([`delta::patch_plan`]) that rebuilds only dirty degree buckets,
//!   bit-for-bit equal to a from-scratch rebuild.
//! * [`serve`] — native serving subsystem: multi-tenant bounded-queue
//!   server executing column-fused SpMM/GCN batches through
//!   [`pipeline`] on CPU — the request path that works offline. Tenants
//!   accept `UpdateGraph` requests with epoch-versioned plan swaps.
//! * [`store`] — durability layer: per-tenant generational graph
//!   snapshots plus a delta WAL (every `UpdateGraph` batch logged
//!   before it applies), crash recovery through the [`delta`] replay
//!   path with plan-fingerprint assertion, and an env-driven
//!   fault-injection harness (torn tail, truncated snapshot, checksum
//!   flip, disk full) — see DESIGN §11.
//! * [`train`] — native training subsystem: full-graph GCN backprop
//!   (forward with tape → masked softmax cross-entropy → backward →
//!   SGD/Adam) entirely on the parallel SpMM pipeline; the backward
//!   SpMM runs against a cached transposed plan (or the forward plan
//!   itself when `Â` is symmetric).
//! * [`tune`] — closed-loop plan tuning: fits a per-kernel cost model
//!   to the measured per-shard timeline in [`obs`], re-cuts shard
//!   boundaries against predicted cost, and revisits the dense/sparse
//!   crossover — swapped through [`pipeline::PlanCache::refresh`]
//!   with bit-identical output guaranteed.
//! * [`runtime`] — PJRT wrapper loading AOT artifacts (`*.hlo.txt`).
//! * [`obs`] — unified tracing & profiling: span timers with
//!   thread-local nesting, typed counters/gauges, fixed log-bucket
//!   histograms, the per-shard SpMM execution timeline (busy time
//!   **and** bytes moved, so shards report achieved GB/s), the
//!   STREAM-style peak-bandwidth calibration ([`obs::calibrate`],
//!   cached JSON), and the versioned JSON metrics/roofline snapshots
//!   (`accel-gcn profile`, `accel-gcn roofline`,
//!   `serve-native --metrics-out`). The analytic side of the roofline
//!   lives in [`pipeline::TrafficModel`], attached to every plan.
//! * [`metrics`] — serving-facing facade over [`obs`] (counters and
//!   histogram-backed latency recorders).
//! * [`util`] — zero-dependency substrates (RNG, JSON, NPY, CLI, stats,
//!   bench harness) required by the offline build environment.

pub mod util;
pub mod graph;
pub mod partition;
pub mod spmm;
pub mod pipeline;
pub mod delta;
pub mod sim;
pub mod model;
pub mod obs;
pub mod metrics;
pub mod runtime;
pub mod coordinator;
pub mod serve;
pub mod store;
pub mod train;
pub mod tune;
pub mod bench;
