//! Multi-layer GCN forward execution for the native serving path.
//!
//! A [`GcnModel`] is the dense half of a GCN stack (per-layer weight
//! matrix + bias, dims from [`ModelConfig`]); [`GcnForward`] chains
//! `SpMM → X·W + b → ReLU` per layer **in the relabeled domain**
//! (DESIGN §2), keeping the whole batch in one fused `[n × k·d]`
//! matrix from ingress to egress — Accel-GCN's column-dimension insight
//! applied across concurrent requests instead of across lanes.
//!
//! The path is zero-copy end to end: member features are borrowed
//! slices gathered straight into the fused matrix (permuting on the
//! way in), every layer ping-pongs between two reused buffers through
//! [`spmm_block_level_parallel_into`] and a fused-layout parallel
//! affine, and the egress split scatters rows back to the original
//! node order while copying out — no per-layer fuse/split buffers, no
//! `Arc` input copies, no separate permute passes.

use crate::graph::csr::Csr;
use crate::model::ModelConfig;
use crate::pipeline::{spmm_block_level_parallel_into, SpmmPlan};
use crate::util::rng::Pcg;
use crate::util::threadpool::ThreadPool;
use anyhow::Result;
use std::time::Instant;

/// Dense parameters of a GCN stack. Weights are row-major
/// `[d_in × d_out]` per layer; immutable after construction and shared
/// across requests via `Arc` (the `Arc` pointer doubles as the batch
/// grouping key in the server).
#[derive(Debug, Clone)]
pub struct GcnModel {
    pub config: ModelConfig,
    /// `weights[l]` is `[dims[l].0 × dims[l].1]`, row-major.
    pub weights: Vec<Vec<f32>>,
    /// `biases[l]` is `[dims[l].1]`.
    pub biases: Vec<Vec<f32>>,
}

impl GcnModel {
    /// Seeded Glorot-style random init (deterministic across machines,
    /// like everything in this tree).
    pub fn random(config: ModelConfig, seed: u64) -> GcnModel {
        let mut rng = Pcg::seed_from(seed ^ 0x6c0d_e1);
        let dims = config.layer_dims();
        let mut weights = Vec::with_capacity(dims.len());
        let mut biases = Vec::with_capacity(dims.len());
        for &(din, dout) in &dims {
            let scale = (2.0 / (din + dout) as f64).sqrt() as f32;
            weights.push((0..din * dout).map(|_| (rng.f32() - 0.5) * 2.0 * scale).collect());
            biases.push((0..dout).map(|_| (rng.f32() - 0.5) * 0.1).collect());
        }
        GcnModel { config, weights, biases }
    }

    /// `(in, out)` dims per layer.
    pub fn dims(&self) -> Vec<(usize, usize)> {
        self.config.layer_dims()
    }

    /// The widest per-member column count any layer feeds into SpMM —
    /// what the batcher must budget per member when packing a fused
    /// GCN batch against the width ladder.
    pub fn max_width(&self) -> usize {
        self.dims().iter().map(|&(din, _)| din).max().unwrap_or(0)
    }

    /// Floating-point operations of one SpMM-side forward pass for `k`
    /// fused members on an `nnz`-edge graph: `2·nnz·k·d_in` per layer
    /// (the GFLOP/s numerator the serve metrics record).
    pub fn spmm_flops(&self, nnz: usize, k: usize) -> f64 {
        self.dims()
            .iter()
            .map(|&(din, _)| crate::spmm::spmm_flops(nnz, k * din))
            .sum()
    }
}

/// `orow = xrow · w + b`, optionally ReLU-clamped — the one per-row
/// affine kernel both the sequential reference and the parallel fused
/// path run (shared with the training forward, [`crate::train`]).
#[inline]
pub(crate) fn affine_one_row(
    xrow: &[f32],
    w: &[f32],
    dout: usize,
    b: &[f32],
    relu: bool,
    orow: &mut [f32],
) {
    orow.copy_from_slice(b);
    // k-outer ordering: the inner j-loop streams one w row (cache-friendly)
    for (k, &xv) in xrow.iter().enumerate() {
        if xv == 0.0 {
            continue;
        }
        let wrow = &w[k * dout..(k + 1) * dout];
        for j in 0..dout {
            orow[j] += xv * wrow[j];
        }
    }
    if relu {
        for v in orow.iter_mut() {
            if *v < 0.0 {
                *v = 0.0;
            }
        }
    }
}

/// `out = x · w + b`, optionally ReLU-clamped. `x` is `[rows × din]`
/// row-major, `w` is `[din × dout]` row-major.
pub(crate) fn affine_rows(
    x: &[f32],
    rows: usize,
    din: usize,
    w: &[f32],
    dout: usize,
    b: &[f32],
    relu: bool,
) -> Vec<f32> {
    debug_assert_eq!(x.len(), rows * din);
    debug_assert_eq!(w.len(), din * dout);
    debug_assert_eq!(b.len(), dout);
    let mut out = vec![0f32; rows * dout];
    for r in 0..rows {
        affine_one_row(&x[r * din..(r + 1) * din], w, dout, b, relu, &mut out[r * dout..(r + 1) * dout]);
    }
    out
}

/// Fused-layout parallel affine: `x` is `[n × k·din]` (members
/// column-concatenated), `out` is `[n × k·dout]`; each member's columns
/// go through `x·w + b` (shared weights), optional ReLU. Rows are
/// chunked across the pool with scoped jobs writing disjoint spans of
/// `out` — no staging buffers, no input copies. With `k = 1` this is a
/// plain row-chunked parallel affine, which is how the training forward
/// ([`crate::train::tape`]) shares the serving path's dense kernel.
pub(crate) fn affine_fused_parallel(
    pool: &ThreadPool,
    x: &[f32],
    n: usize,
    k: usize,
    din: usize,
    w: &[f32],
    dout: usize,
    b: &[f32],
    relu: bool,
    out: &mut [f32],
) {
    let wi = k * din;
    let wo = k * dout;
    debug_assert_eq!(x.len(), n * wi);
    debug_assert_eq!(out.len(), n * wo);
    debug_assert_eq!(w.len(), din * dout);
    debug_assert_eq!(b.len(), dout);
    if n == 0 || k == 0 || wo == 0 {
        return;
    }
    let chunk = n.div_ceil(pool.size().max(1)).max(1);
    let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = out
        .chunks_mut(chunk * wo)
        .enumerate()
        .map(|(ci, ochunk)| {
            let rows = ochunk.len() / wo;
            let lo = ci * chunk;
            let xs = &x[lo * wi..(lo + rows) * wi];
            Box::new(move || {
                for r in 0..rows {
                    for m in 0..k {
                        affine_one_row(
                            &xs[r * wi + m * din..r * wi + (m + 1) * din],
                            w,
                            dout,
                            b,
                            relu,
                            &mut ochunk[r * wo + m * dout..r * wo + (m + 1) * dout],
                        );
                    }
                }
            }) as Box<dyn FnOnce() + Send + '_>
        })
        .collect();
    pool.scoped_run(jobs);
}

/// Timings of one fused forward pass, for the per-stage recorders.
#[derive(Clone, Copy, Debug, Default)]
pub struct ForwardTimings {
    pub spmm_secs: f64,
    pub dense_secs: f64,
}

/// The GCN layer stack bound to one plan (over the internal-domain
/// adjacency) and pool.
pub struct GcnForward<'a> {
    pub plan: &'a SpmmPlan,
    pub pool: &'a ThreadPool,
}

impl GcnForward<'_> {
    /// Forward `k` borrowed member feature matrices (each
    /// `[n × in_dim]`) through the stack as one fused batch.
    ///
    /// `perm`, when given, maps internal (relabeled) row `i` to the
    /// member matrices' row `perm[i]` — the registry entry's
    /// permutation. Ingress gathers member rows through it while fusing
    /// members column-wise; egress scatters result rows back through it
    /// while splitting — so callers pass features and receive results
    /// in the **original** node order with zero standalone permute
    /// passes. With `None`, features and results stay in the plan's own
    /// row order.
    ///
    /// Between ingress and egress each layer runs one wide SpMM and one
    /// fused-layout affine (ReLU on all but the last layer), ping-pong
    /// between two buffers reused across layers.
    pub fn forward(
        &self,
        model: &GcnModel,
        xs: &[&[f32]],
        perm: Option<&[u32]>,
    ) -> Result<(Vec<Vec<f32>>, ForwardTimings)> {
        let n = self.plan.n_rows();
        let k = xs.len();
        anyhow::ensure!(k > 0, "empty GCN batch");
        let dims = model.dims();
        anyhow::ensure!(!dims.is_empty(), "model has no layers");
        if let Some(p) = perm {
            anyhow::ensure!(p.len() == n, "permutation/plan size mismatch");
        }
        let in_dim = dims[0].0;
        for (m, x) in xs.iter().enumerate() {
            anyhow::ensure!(x.len() == n * in_dim, "member {m}: feature shape mismatch");
        }

        // ingress: gather member rows (through perm) into the fused
        // [n × k·in_dim] matrix — the only full copy on the way in
        let width = k * in_dim;
        let mut h = vec![0f32; n * width];
        for (m, x) in xs.iter().enumerate() {
            let at = m * in_dim;
            for i in 0..n {
                let src = perm.map_or(i, |p| p[i] as usize) * in_dim;
                h[i * width + at..i * width + at + in_dim]
                    .copy_from_slice(&x[src..src + in_dim]);
            }
        }

        let mut agg: Vec<f32> = Vec::new();
        let mut nxt: Vec<f32> = Vec::new();
        let mut t = ForwardTimings::default();
        for (l, &(din, dout)) in dims.iter().enumerate() {
            let width = k * din;
            debug_assert_eq!(h.len(), n * width);
            // Â·[H₁ … Hₖ] in one traversal of the adjacency
            agg.resize(n * width, 0.0);
            let t0 = Instant::now();
            spmm_block_level_parallel_into(self.plan, &h, width, self.pool, &mut agg);
            t.spmm_secs += t0.elapsed().as_secs_f64();
            // fused-layout dense affine, members sharing the layer weights
            let t1 = Instant::now();
            let relu = l + 1 < dims.len();
            nxt.resize(n * k * dout, 0.0);
            affine_fused_parallel(
                self.pool,
                &agg,
                n,
                k,
                din,
                &model.weights[l],
                dout,
                &model.biases[l],
                relu,
                &mut nxt,
            );
            t.dense_secs += t1.elapsed().as_secs_f64();
            std::mem::swap(&mut h, &mut nxt);
        }

        // egress: split members, scattering rows back through perm —
        // the only full copy on the way out
        let out_dim = dims.last().expect("non-empty").1;
        let width = k * out_dim;
        let mut outs = Vec::with_capacity(k);
        for m in 0..k {
            let at = m * out_dim;
            let mut out = vec![0f32; n * out_dim];
            for i in 0..n {
                let dst = perm.map_or(i, |p| p[i] as usize) * out_dim;
                out[dst..dst + out_dim].copy_from_slice(&h[i * width + at..i * width + at + out_dim]);
            }
            outs.push(out);
        }
        Ok((outs, t))
    }
}

/// Numeric ground truth: the same stack executed with the dense CSR
/// traversal in the **original** domain (what serve responses are
/// verified against).
pub fn reference_forward(csr: &Csr, model: &GcnModel, x: &[f32]) -> Vec<f32> {
    let dims = model.dims();
    let mut h: Vec<f32> = Vec::new();
    for (l, &(din, dout)) in dims.iter().enumerate() {
        let input: &[f32] = if l == 0 { x } else { &h };
        let agg = csr.spmm_dense(input, din);
        h = affine_rows(
            &agg,
            csr.n_rows,
            din,
            &model.weights[l],
            dout,
            &model.biases[l],
            l + 1 < dims.len(),
        );
    }
    if dims.is_empty() {
        x.to_vec()
    } else {
        h
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::partition::patterns::PartitionParams;
    use crate::serve::registry::GraphRegistry;
    use crate::spmm::verify::assert_allclose;
    use std::sync::Arc;

    fn random_csr(seed: u64, n: usize) -> Csr {
        let mut rng = Pcg::seed_from(seed);
        let mut edges = vec![(0u32, 0u32, 1.0f32)];
        for r in 0..n {
            for _ in 0..rng.range(0, 7) {
                edges.push((r as u32, rng.range(0, n) as u32, rng.f32() + 0.1));
            }
        }
        Csr::from_edges(n, n, &edges).unwrap()
    }

    #[test]
    fn model_shapes() {
        let m = GcnModel::random(ModelConfig::gcn(16, 8, 4, 3), 1);
        assert_eq!(m.weights.len(), 3);
        assert_eq!(m.weights[0].len(), 16 * 8);
        assert_eq!(m.weights[1].len(), 8 * 8);
        assert_eq!(m.weights[2].len(), 8 * 4);
        assert_eq!(m.biases[2].len(), 4);
        assert_eq!(m.max_width(), 16);
        // per-layer 2·nnz·k·din: 2·10·2·(16+8+8)
        assert_eq!(m.spmm_flops(10, 2), 2.0 * 10.0 * 2.0 * 32.0);
    }

    #[test]
    fn affine_matches_hand_computation() {
        // x = [[1, 2]], w = [[1, 0], [0, -1]], b = [10, 10]
        let out = affine_rows(&[1.0, 2.0], 1, 2, &[1.0, 0.0, 0.0, -1.0], 2, &[10.0, 10.0], false);
        assert_eq!(out, vec![11.0, 8.0]);
        let relu = affine_rows(&[1.0, 2.0], 1, 2, &[1.0, 0.0, 0.0, -1.0], 2, &[0.0, 0.0], true);
        assert_eq!(relu, vec![1.0, 0.0]);
    }

    #[test]
    fn parallel_affine_matches_sequential() {
        // k = 1 degenerates the fused layout to a plain row-chunked affine
        let model = GcnModel::random(ModelConfig::gcn(6, 5, 3, 2), 2);
        let rows = 37;
        let mut rng = Pcg::seed_from(3);
        let x: Vec<f32> = (0..rows * 6).map(|_| rng.f32() - 0.5).collect();
        let want = affine_rows(&x, rows, 6, &model.weights[0], 5, &model.biases[0], true);
        let pool = ThreadPool::new(4);
        let mut got = vec![0f32; rows * 5];
        affine_fused_parallel(&pool, &x, rows, 1, 6, &model.weights[0], 5, &model.biases[0], true, &mut got);
        assert_allclose(&got, &want, 1e-5, 1e-5, "parallel affine");
    }

    #[test]
    fn fused_affine_matches_per_member() {
        // k members in fused layout == each member through affine_rows
        let model = GcnModel::random(ModelConfig::gcn(5, 4, 2, 2), 9);
        let (n, k, din, dout) = (23, 3, 5, 4);
        let mut rng = Pcg::seed_from(31);
        let fused: Vec<f32> = (0..n * k * din).map(|_| rng.f32() - 0.5).collect();
        let pool = ThreadPool::new(3);
        let mut out = vec![0f32; n * k * dout];
        affine_fused_parallel(
            &pool, &fused, n, k, din, &model.weights[0], dout, &model.biases[0], true, &mut out,
        );
        for m in 0..k {
            let xm: Vec<f32> = (0..n)
                .flat_map(|r| fused[r * k * din + m * din..r * k * din + (m + 1) * din].to_vec())
                .collect();
            let want = affine_rows(&xm, n, din, &model.weights[0], dout, &model.biases[0], true);
            for r in 0..n {
                for j in 0..dout {
                    let got = out[r * k * dout + m * dout + j];
                    let w = want[r * dout + j];
                    assert!((got - w).abs() < 1e-5, "m={m} r={r} j={j}: {got} vs {w}");
                }
            }
        }
    }

    #[test]
    fn fused_forward_matches_reference_per_member() {
        let csr = random_csr(7, 45);
        let model = Arc::new(GcnModel::random(ModelConfig::gcn(8, 6, 3, 2), 11));
        let reg = GraphRegistry::new();
        let h = reg.register("g", &csr).unwrap();
        let entry = reg.get(h).unwrap();
        let plan =
            Arc::new(SpmmPlan::build((*entry.relabeled).clone(), PartitionParams::default()));
        let pool = ThreadPool::new(3);
        let fw = GcnForward { plan: &plan, pool: &pool };

        let mut rng = Pcg::seed_from(5);
        let xs: Vec<Vec<f32>> =
            (0..3).map(|_| (0..45 * 8).map(|_| rng.f32() - 0.5).collect()).collect();
        // original-domain features in, original-domain results out:
        // permutes are fused into the forward's ingress/egress copies
        let xs_refs: Vec<&[f32]> = xs.iter().map(Vec::as_slice).collect();
        let (outs, timings) = fw.forward(&model, &xs_refs, Some(&entry.perm)).unwrap();
        assert!(timings.spmm_secs >= 0.0 && timings.dense_secs >= 0.0);
        for (m, got) in outs.iter().enumerate() {
            let want = reference_forward(&csr, &model, &xs[m]);
            assert_allclose(got, &want, 1e-3, 1e-3, "fused member vs reference");
        }
    }

    #[test]
    fn forward_without_perm_runs_in_plan_domain() {
        // with perm: None the stack runs directly in the plan's own row
        // order — over the original adjacency that IS the original order
        let csr = random_csr(13, 30);
        let model = GcnModel::random(ModelConfig::gcn(6, 4, 2, 2), 3);
        let plan = SpmmPlan::build(csr.clone(), PartitionParams::default());
        let pool = ThreadPool::new(2);
        let fw = GcnForward { plan: &plan, pool: &pool };
        let mut rng = Pcg::seed_from(8);
        let x: Vec<f32> = (0..30 * 6).map(|_| rng.f32() - 0.5).collect();
        let (outs, _) = fw.forward(&model, &[&x], None).unwrap();
        let want = reference_forward(&csr, &model, &x);
        assert_allclose(&outs[0], &want, 1e-3, 1e-3, "no-perm forward");
    }

    #[test]
    fn forward_rejects_bad_shapes() {
        let csr = random_csr(17, 12);
        let model = GcnModel::random(ModelConfig::gcn(4, 3, 2, 2), 4);
        let plan = SpmmPlan::build(csr, PartitionParams::default());
        let pool = ThreadPool::new(1);
        let fw = GcnForward { plan: &plan, pool: &pool };
        assert!(fw.forward(&model, &[], None).is_err(), "empty batch");
        let short = vec![0f32; 5];
        assert!(fw.forward(&model, &[&short], None).is_err(), "bad member shape");
        let x = vec![0f32; 12 * 4];
        let bad_perm = vec![0u32; 3];
        assert!(fw.forward(&model, &[&x], Some(&bad_perm)).is_err(), "bad perm length");
    }

    #[test]
    fn relabeled_plan_spmm_stays_in_relabeled_domain() {
        // what the serve SpMM group relies on: for a plan built FROM a
        // relabeled matrix, the executor's original-row-order result IS
        // the relabeled domain (the internal degree sort is the identity)
        use crate::pipeline::spmm_block_level_parallel;
        let csr = random_csr(9, 30);
        let reg = GraphRegistry::new();
        let entry = reg.get(reg.register("g", &csr).unwrap()).unwrap();
        let plan =
            Arc::new(SpmmPlan::build((*entry.relabeled).clone(), PartitionParams::default()));
        // identity invariant: sorting an already-sorted matrix is a no-op
        assert!(plan.sorted.perm.iter().enumerate().all(|(i, &p)| p as usize == i));
        let f = 4;
        let mut rng = Pcg::seed_from(17);
        let x: Vec<f32> = (0..30 * f).map(|_| rng.f32() - 0.5).collect();
        let x_rel = entry.permute_rows(&x, f);
        let pool = ThreadPool::new(2);
        let y_rel = spmm_block_level_parallel(&plan, &x_rel, f, &pool);
        let got = entry.unpermute_rows(&y_rel, f);
        let want = csr.spmm_dense(&x, f);
        assert_allclose(&got, &want, 1e-4, 1e-4, "relabeled spmm");
    }
}
