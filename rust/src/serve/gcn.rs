//! Multi-layer GCN forward execution for the native serving path.
//!
//! A [`GcnModel`] is the dense half of a GCN stack (per-layer weight
//! matrix + bias, dims from [`ModelConfig`]); [`GcnForward`] chains
//! `SpMM → X·W + b → ReLU` per layer **in the relabeled domain**
//! (DESIGN §2), so consecutive layers compose with zero per-layer
//! unpermutes, and fuses all members of a batch into one wide SpMM per
//! layer — Accel-GCN's column-dimension insight applied across
//! concurrent requests instead of across lanes.

use crate::graph::csr::Csr;
use crate::model::ModelConfig;
use crate::pipeline::{spmm_block_level_parallel, SpmmPlan};
use crate::util::rng::Pcg;
use crate::util::threadpool::ThreadPool;
use anyhow::Result;
use std::sync::Arc;
use std::time::Instant;

/// Dense parameters of a GCN stack. Weights are row-major
/// `[d_in × d_out]` per layer; immutable after construction and shared
/// across requests via `Arc` (the `Arc` pointer doubles as the batch
/// grouping key in the server).
#[derive(Debug, Clone)]
pub struct GcnModel {
    pub config: ModelConfig,
    /// `weights[l]` is `[dims[l].0 × dims[l].1]`, row-major.
    pub weights: Vec<Vec<f32>>,
    /// `biases[l]` is `[dims[l].1]`.
    pub biases: Vec<Vec<f32>>,
}

impl GcnModel {
    /// Seeded Glorot-style random init (deterministic across machines,
    /// like everything in this tree).
    pub fn random(config: ModelConfig, seed: u64) -> GcnModel {
        let mut rng = Pcg::seed_from(seed ^ 0x6c0d_e1);
        let dims = config.layer_dims();
        let mut weights = Vec::with_capacity(dims.len());
        let mut biases = Vec::with_capacity(dims.len());
        for &(din, dout) in &dims {
            let scale = (2.0 / (din + dout) as f64).sqrt() as f32;
            weights.push((0..din * dout).map(|_| (rng.f32() - 0.5) * 2.0 * scale).collect());
            biases.push((0..dout).map(|_| (rng.f32() - 0.5) * 0.1).collect());
        }
        GcnModel { config, weights, biases }
    }

    /// `(in, out)` dims per layer.
    pub fn dims(&self) -> Vec<(usize, usize)> {
        self.config.layer_dims()
    }

    /// The widest per-member column count any layer feeds into SpMM —
    /// what the batcher must budget per member when packing a fused
    /// GCN batch against the width ladder.
    pub fn max_width(&self) -> usize {
        self.dims().iter().map(|&(din, _)| din).max().unwrap_or(0)
    }
}

/// `out = x · w + b`, optionally ReLU-clamped. `x` is `[rows × din]`
/// row-major, `w` is `[din × dout]` row-major.
fn affine_rows(x: &[f32], rows: usize, din: usize, w: &[f32], dout: usize, b: &[f32], relu: bool) -> Vec<f32> {
    debug_assert_eq!(x.len(), rows * din);
    debug_assert_eq!(w.len(), din * dout);
    debug_assert_eq!(b.len(), dout);
    let mut out = vec![0f32; rows * dout];
    for r in 0..rows {
        let orow = &mut out[r * dout..(r + 1) * dout];
        orow.copy_from_slice(b);
        let xrow = &x[r * din..(r + 1) * din];
        // k-outer ordering: the inner j-loop streams one w row (cache-friendly)
        for (k, &xv) in xrow.iter().enumerate() {
            if xv == 0.0 {
                continue;
            }
            let wrow = &w[k * dout..(k + 1) * dout];
            for j in 0..dout {
                orow[j] += xv * wrow[j];
            }
        }
        if relu {
            for v in orow.iter_mut() {
                if *v < 0.0 {
                    *v = 0.0;
                }
            }
        }
    }
    out
}

/// Parallel `x · w + b` over the worker pool: rows are chunked, each
/// chunk runs [`affine_rows`], results concatenate in row order.
pub fn dense_affine_parallel(
    pool: &ThreadPool,
    x: &Arc<Vec<f32>>,
    rows: usize,
    din: usize,
    model: &Arc<GcnModel>,
    layer: usize,
    relu: bool,
) -> Vec<f32> {
    let threads = pool.size().max(1);
    let chunk = rows.div_ceil(threads).max(1);
    let jobs: Vec<_> = (0..rows)
        .step_by(chunk)
        .map(|lo| {
            let hi = (lo + chunk).min(rows);
            let x = Arc::clone(x);
            let model = Arc::clone(model);
            move || {
                let dout = model.dims()[layer].1;
                affine_rows(
                    &x[lo * din..hi * din],
                    hi - lo,
                    din,
                    &model.weights[layer],
                    dout,
                    &model.biases[layer],
                    relu,
                )
            }
        })
        .collect();
    pool.run_all(jobs).concat()
}

/// Run the parallel block-level SpMM for a plan built **from** a
/// relabeled adjacency, returning the result in that same domain.
///
/// The relabeled matrix's rows already ascend by degree, so the plan's
/// internal degree sort is the identity and the sorted-domain result of
/// [`spmm_block_level_parallel`] *is* the relabeled-domain result. The
/// identity check is O(n) — free next to the O(nnz·f) SpMM — and the
/// fallback keeps this correct even for a plan that was built from a
/// non-relabeled matrix.
pub fn spmm_relabeled(plan: &Arc<SpmmPlan>, x: &Arc<Vec<f32>>, f: usize, pool: &ThreadPool) -> Vec<f32> {
    let y = spmm_block_level_parallel(plan, x, f, pool);
    let identity = plan.sorted.perm.iter().enumerate().all(|(i, &p)| p as usize == i);
    if identity {
        y
    } else {
        plan.sorted.unpermute_rows(&y, f)
    }
}

/// Timings of one fused forward pass, for the per-stage recorders.
#[derive(Clone, Copy, Debug, Default)]
pub struct ForwardTimings {
    pub spmm_secs: f64,
    pub dense_secs: f64,
}

/// The GCN layer stack bound to one relabeled-domain plan and pool.
pub struct GcnForward<'a> {
    pub plan: &'a Arc<SpmmPlan>,
    pub pool: &'a ThreadPool,
}

impl GcnForward<'_> {
    /// Forward `k` member feature matrices (each `[n × in_dim]`,
    /// **relabeled** row order) through the stack as one fused batch:
    /// each layer concatenates the members column-wise, runs a single
    /// wide SpMM, splits, and applies the dense affine per member
    /// (ReLU on all but the last layer). Returns per-member
    /// `[n × out_dim]` matrices, still in the relabeled domain.
    pub fn forward(&self, model: &Arc<GcnModel>, xs: Vec<Vec<f32>>) -> Result<(Vec<Vec<f32>>, ForwardTimings)> {
        let n = self.plan.n_rows();
        let k = xs.len();
        anyhow::ensure!(k > 0, "empty GCN batch");
        let dims = model.dims();
        let mut hs = xs;
        let mut t = ForwardTimings::default();
        for (l, &(din, dout)) in dims.iter().enumerate() {
            for h in &hs {
                anyhow::ensure!(h.len() == n * din, "layer {l}: member shape mismatch");
            }
            // fuse: Â·[H₁ … Hₖ] in one traversal of the adjacency
            let width = k * din;
            let mut fused = vec![0f32; n * width];
            for (m, h) in hs.iter().enumerate() {
                for r in 0..n {
                    fused[r * width + m * din..r * width + (m + 1) * din]
                        .copy_from_slice(&h[r * din..(r + 1) * din]);
                }
            }
            let fused = Arc::new(fused);
            let t0 = Instant::now();
            let agg = spmm_relabeled(self.plan, &fused, width, self.pool);
            t.spmm_secs += t0.elapsed().as_secs_f64();
            // split + dense per member
            let t1 = Instant::now();
            let relu = l + 1 < dims.len();
            let mut next = Vec::with_capacity(k);
            for m in 0..k {
                let mut part = vec![0f32; n * din];
                for r in 0..n {
                    part[r * din..(r + 1) * din]
                        .copy_from_slice(&agg[r * width + m * din..r * width + (m + 1) * din]);
                }
                let part = Arc::new(part);
                next.push(dense_affine_parallel(self.pool, &part, n, din, model, l, relu));
                debug_assert_eq!(next.last().unwrap().len(), n * dout);
            }
            t.dense_secs += t1.elapsed().as_secs_f64();
            hs = next;
        }
        Ok((hs, t))
    }
}

/// Numeric ground truth: the same stack executed with the dense CSR
/// traversal in the **original** domain (what serve responses are
/// verified against).
pub fn reference_forward(csr: &Csr, model: &GcnModel, x: &[f32]) -> Vec<f32> {
    let mut h = x.to_vec();
    let dims = model.dims();
    for (l, &(din, dout)) in dims.iter().enumerate() {
        let agg = csr.spmm_dense(&h, din);
        h = affine_rows(
            &agg,
            csr.n_rows,
            din,
            &model.weights[l],
            dout,
            &model.biases[l],
            l + 1 < dims.len(),
        );
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::partition::patterns::PartitionParams;
    use crate::serve::registry::GraphRegistry;
    use crate::spmm::verify::assert_allclose;

    fn random_csr(seed: u64, n: usize) -> Csr {
        let mut rng = Pcg::seed_from(seed);
        let mut edges = vec![(0u32, 0u32, 1.0f32)];
        for r in 0..n {
            for _ in 0..rng.range(0, 7) {
                edges.push((r as u32, rng.range(0, n) as u32, rng.f32() + 0.1));
            }
        }
        Csr::from_edges(n, n, &edges).unwrap()
    }

    #[test]
    fn model_shapes() {
        let m = GcnModel::random(ModelConfig::gcn(16, 8, 4, 3), 1);
        assert_eq!(m.weights.len(), 3);
        assert_eq!(m.weights[0].len(), 16 * 8);
        assert_eq!(m.weights[1].len(), 8 * 8);
        assert_eq!(m.weights[2].len(), 8 * 4);
        assert_eq!(m.biases[2].len(), 4);
        assert_eq!(m.max_width(), 16);
    }

    #[test]
    fn affine_matches_hand_computation() {
        // x = [[1, 2]], w = [[1, 0], [0, -1]], b = [10, 10]
        let out = affine_rows(&[1.0, 2.0], 1, 2, &[1.0, 0.0, 0.0, -1.0], 2, &[10.0, 10.0], false);
        assert_eq!(out, vec![11.0, 8.0]);
        let relu = affine_rows(&[1.0, 2.0], 1, 2, &[1.0, 0.0, 0.0, -1.0], 2, &[0.0, 0.0], true);
        assert_eq!(relu, vec![1.0, 0.0]);
    }

    #[test]
    fn parallel_affine_matches_sequential() {
        let model = Arc::new(GcnModel::random(ModelConfig::gcn(6, 5, 3, 2), 2));
        let rows = 37;
        let mut rng = Pcg::seed_from(3);
        let x: Vec<f32> = (0..rows * 6).map(|_| rng.f32() - 0.5).collect();
        let want = affine_rows(&x, rows, 6, &model.weights[0], 5, &model.biases[0], true);
        let pool = ThreadPool::new(4);
        let got = dense_affine_parallel(&pool, &Arc::new(x), rows, 6, &model, 0, true);
        assert_allclose(&got, &want, 1e-5, 1e-5, "parallel affine");
    }

    #[test]
    fn fused_forward_matches_reference_per_member() {
        let csr = random_csr(7, 45);
        let model =
            Arc::new(GcnModel::random(ModelConfig::gcn(8, 6, 3, 2), 11));
        let reg = GraphRegistry::new();
        let h = reg.register("g", &csr).unwrap();
        let entry = reg.get(h).unwrap();
        let plan =
            Arc::new(SpmmPlan::build((*entry.relabeled).clone(), PartitionParams::default()));
        let pool = ThreadPool::new(3);
        let fw = GcnForward { plan: &plan, pool: &pool };

        let mut rng = Pcg::seed_from(5);
        let xs: Vec<Vec<f32>> =
            (0..3).map(|_| (0..45 * 8).map(|_| rng.f32() - 0.5).collect()).collect();
        let xs_rel: Vec<Vec<f32>> = xs.iter().map(|x| entry.permute_rows(x, 8)).collect();
        let (outs, timings) = fw.forward(&model, xs_rel).unwrap();
        assert!(timings.spmm_secs >= 0.0 && timings.dense_secs >= 0.0);
        for (m, out_rel) in outs.iter().enumerate() {
            let got = entry.unpermute_rows(out_rel, 3);
            let want = reference_forward(&csr, &model, &xs[m]);
            assert_allclose(&got, &want, 1e-3, 1e-3, "fused member vs reference");
        }
    }

    #[test]
    fn spmm_relabeled_identity_domain() {
        let csr = random_csr(9, 30);
        let reg = GraphRegistry::new();
        let entry = reg.get(reg.register("g", &csr).unwrap()).unwrap();
        let plan =
            Arc::new(SpmmPlan::build((*entry.relabeled).clone(), PartitionParams::default()));
        // identity invariant: sorting an already-sorted matrix is a no-op
        assert!(plan.sorted.perm.iter().enumerate().all(|(i, &p)| p as usize == i));
        let f = 4;
        let mut rng = Pcg::seed_from(17);
        let x: Vec<f32> = (0..30 * f).map(|_| rng.f32() - 0.5).collect();
        let x_rel = Arc::new(entry.permute_rows(&x, f));
        let pool = ThreadPool::new(2);
        let y_rel = spmm_relabeled(&plan, &x_rel, f, &pool);
        let got = entry.unpermute_rows(&y_rel, f);
        let want = csr.spmm_dense(&x, f);
        assert_allclose(&got, &want, 1e-4, 1e-4, "relabeled spmm");
    }
}
