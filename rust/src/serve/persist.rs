//! Serve-side durability glue: maps [`GraphHandle`]s to their
//! [`TenantStore`]s and WAL writers, and enforces the ordering that
//! makes recovery sound:
//!
//! 1. **register** → snapshot generation 1 at epoch 0 is written
//!    *before* the handle is returned (a registered tenant is always
//!    recoverable);
//! 2. **update** → the batch record is appended (and, under
//!    `--fsync always`, synced) *before*
//!    [`GraphRegistry::update`](super::GraphRegistry::update) runs —
//!    the worker applies updates only after the WAL append succeeds,
//!    so nothing is ever applied that a restart cannot replay;
//! 3. **after apply** → a commit record seals the new epoch with the
//!    relabeled fingerprint (advisory: recovery treats a missing final
//!    seal as "unverified", not fatal);
//! 4. **periodically** → a fresh snapshot generation + WAL compaction
//!    keep the replay tail short; the compaction cutoff is the epoch
//!    of the *older* retained generation so fallback recovery still
//!    has full coverage.

use super::registry::{GraphEntry, GraphHandle};
use crate::graph::csr::Csr;
use crate::pipeline::GraphFingerprint;
use crate::store::{
    FaultPlan, FsyncPolicy, Snapshot, Store, StoreError, TenantStore, WalRecord, WalWriter,
};
use crate::delta::EdgeUpdate;
use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::Mutex;

/// Durability configuration carried by
/// [`ServeConfig`](super::ServeConfig).
#[derive(Clone, Debug)]
pub struct PersistConfig {
    /// Root data directory (`serve-native --data-dir`).
    pub data_dir: PathBuf,
    /// Fsync policy for WAL appends and snapshot writes.
    pub fsync: FsyncPolicy,
    /// Write a fresh snapshot generation (and compact the WAL) every
    /// this many applied updates per tenant; 0 = only the registration
    /// snapshot.
    pub snapshot_every: usize,
    /// Explicit fault-injection spec (same grammar as the
    /// `ACCEL_GCN_FAULT` env var, see
    /// [`FaultPlan::parse`](crate::store::FaultPlan::parse)); `None`
    /// falls back to the env var. Lets tests and `--fault` arm faults
    /// without mutating process-global state.
    pub fault_spec: Option<String>,
}

impl PersistConfig {
    pub fn new(data_dir: impl Into<PathBuf>) -> PersistConfig {
        PersistConfig {
            data_dir: data_dir.into(),
            fsync: FsyncPolicy::Always,
            snapshot_every: 0,
            fault_spec: None,
        }
    }
}

struct TenantPersist {
    ts: TenantStore,
    wal: WalWriter,
    /// Applied updates since the last snapshot generation.
    updates_since_snapshot: usize,
}

/// Shared persistence state: the open [`Store`] plus per-handle WAL
/// writers. Appends happen only on the worker thread; the map lock is
/// uncontended in steady state.
pub struct ServePersist {
    store: Store,
    snapshot_every: usize,
    tenants: Mutex<HashMap<GraphHandle, TenantPersist>>,
}

impl std::fmt::Debug for ServePersist {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ServePersist")
            .field("root", &self.store.root())
            .field("tenants", &self.tenants.lock().unwrap().len())
            .finish()
    }
}

impl ServePersist {
    pub fn open(cfg: &PersistConfig) -> Result<ServePersist, StoreError> {
        let store = match &cfg.fault_spec {
            Some(spec) => {
                Store::open_with_faults(&cfg.data_dir, cfg.fsync, FaultPlan::parse(spec))?
            }
            None => Store::open(&cfg.data_dir, cfg.fsync)?,
        };
        if store.faults().any() {
            eprintln!("[store] fault injection armed: {:?}", store.faults());
        }
        Ok(ServePersist {
            store,
            snapshot_every: cfg.snapshot_every,
            tenants: Mutex::new(HashMap::new()),
        })
    }

    pub fn store(&self) -> &Store {
        &self.store
    }

    /// True when the data directory already holds tenant state — the
    /// caller should recover instead of registering fresh.
    pub fn has_tenants(&self) -> Result<bool, StoreError> {
        Ok(!self.store.tenant_dirs()?.is_empty())
    }

    /// Durably create a **new** tenant: write snapshot generation 1 at
    /// the entry's epoch, open the WAL. Refuses (typed) when state for
    /// the name already exists — re-registering over history would
    /// fork it.
    pub fn attach_new(&self, handle: GraphHandle, entry: &GraphEntry, csr: &Csr)
        -> Result<(), StoreError> {
        let ts = self.store.tenant(&entry.name)?;
        if ts.exists() {
            return Err(StoreError::TenantExists { dir: ts.dir().to_path_buf() });
        }
        ts.write_snapshot(&Snapshot {
            name: entry.name.clone(),
            epoch: entry.epoch,
            fingerprint: entry.fingerprint,
            csr: csr.clone(),
        })?;
        let wal =
            WalWriter::open(ts.wal_path(), self.store.fsync(), std::sync::Arc::clone(self.store.faults()))?;
        self.tenants
            .lock()
            .unwrap()
            .insert(handle, TenantPersist { ts, wal, updates_since_snapshot: 0 });
        Ok(())
    }

    /// Adopt a tenant that was just recovered: reuse its on-disk state
    /// and continue appending to its WAL.
    pub fn attach_recovered(&self, handle: GraphHandle, dir_name: &str) -> Result<(), StoreError> {
        let ts = self.store.tenant_by_dir(dir_name);
        let wal = WalWriter::open(
            ts.wal_path(),
            self.store.fsync(),
            std::sync::Arc::clone(self.store.faults()),
        )?;
        self.tenants
            .lock()
            .unwrap()
            .insert(handle, TenantPersist { ts, wal, updates_since_snapshot: 0 });
        Ok(())
    }

    /// Step 2 of the ordering contract: log the batch that will take
    /// `handle` to `epoch`. A typed failure here (disk full, I/O) means
    /// the caller **must not** apply the batch.
    pub fn log_batch(
        &self,
        handle: GraphHandle,
        epoch: u64,
        updates: &[EdgeUpdate],
    ) -> Result<u64, StoreError> {
        let mut map = self.tenants.lock().unwrap();
        let Some(tp) = map.get_mut(&handle) else {
            return Ok(0); // tenant registered before --data-dir existed: not persisted
        };
        tp.wal.append(&WalRecord::Batch { epoch, updates: updates.to_vec() })
    }

    /// Step 3: seal the applied epoch. Advisory — failures are
    /// reported to the caller for counting/warning but must not shed
    /// the (already applied) update.
    pub fn log_commit(
        &self,
        handle: GraphHandle,
        epoch: u64,
        fingerprint: GraphFingerprint,
    ) -> Result<u64, StoreError> {
        let mut map = self.tenants.lock().unwrap();
        let Some(tp) = map.get_mut(&handle) else { return Ok(0) };
        tp.wal.append(&WalRecord::Commit { epoch, fingerprint })
    }

    /// Step 4: after an applied update, possibly roll a new snapshot
    /// generation and compact the WAL. `csr` produces the tenant's
    /// original-domain matrix at `entry`'s epoch — invoked only when a
    /// snapshot is actually due, so the steady-state per-update cost is
    /// a counter bump. Returns the new generation when one was written.
    ///
    /// Failure ordering keeps recovery sound: a failed snapshot write
    /// resets nothing (the WAL tail stays long, retried next update); a
    /// snapshot written but compaction failed leaves a longer-than-
    /// needed WAL, which replay tolerates (epochs ≤ snapshot are
    /// skipped).
    pub fn maybe_snapshot<F>(
        &self,
        handle: GraphHandle,
        entry: &GraphEntry,
        csr: F,
    ) -> Result<Option<u64>, StoreError>
    where
        F: FnOnce() -> Result<Csr, StoreError>,
    {
        let mut map = self.tenants.lock().unwrap();
        let Some(tp) = map.get_mut(&handle) else { return Ok(None) };
        tp.updates_since_snapshot += 1;
        if self.snapshot_every == 0 || tp.updates_since_snapshot < self.snapshot_every {
            return Ok(None);
        }
        let info = tp.ts.write_snapshot(&Snapshot {
            name: entry.name.clone(),
            epoch: entry.epoch,
            fingerprint: entry.fingerprint,
            csr: csr()?,
        })?;
        tp.wal.compact(info.retained_oldest_epoch)?;
        tp.updates_since_snapshot = 0;
        Ok(Some(info.gen))
    }

    /// Does durable state for registry name `name` already exist? Used
    /// by [`Server::register_graph`](super::Server::register_graph) to
    /// refuse before allocating a registry handle.
    pub fn tenant_exists(&self, name: &str) -> Result<bool, StoreError> {
        Ok(self.store.tenant(name)?.exists())
    }

    /// Shutdown: force every WAL to disk (after the worker has joined,
    /// so no appends race this). Errors are returned for logging; all
    /// writers are flushed regardless.
    pub fn flush_all(&self) -> Result<(), StoreError> {
        let mut first_err = None;
        for tp in self.tenants.lock().unwrap().values_mut() {
            if let Err(e) = tp.wal.flush() {
                first_err.get_or_insert(e);
            }
        }
        match first_err {
            None => Ok(()),
            Some(e) => Err(e),
        }
    }
}
