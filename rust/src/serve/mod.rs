//! Native serving subsystem — multi-tenant GCN inference, end-to-end on
//! CPU, with no PJRT dependency.
//!
//! The [`coordinator`](crate::coordinator) routes requests through
//! compiled PJRT artifacts and therefore cannot execute anything when
//! the runtime is the offline stub. This layer is the other half of the
//! story: the **same** column-dimension batching (Accel-GCN §IV's
//! combined-warp insight lifted to whole requests, planned by the
//! shared [`ColumnBatcher`](crate::coordinator::ColumnBatcher) against
//! a *virtual* width ladder) executed through the PR-1 pipeline —
//! cached [`SpmmPlan`](crate::pipeline::SpmmPlan)s and the parallel
//! block-level executor — so `accel-gcn serve-native` serves real
//! traffic offline.
//!
//! * [`registry`] — multi-tenant graph residency: handles, relabeled
//!   adjacencies (DESIGN §2), ingress/egress permutations.
//! * [`gcn`] — the multi-layer forward stack ([`GcnForward`]): fused
//!   `SpMM → X·W + b → ReLU` per layer, chained in the relabeled
//!   domain with zero per-layer unpermutes.
//! * [`server`] — bounded queue + worker loop + batch fusion; see the
//!   module docs for the queue/worker/eviction semantics.
//! * [`metrics`] — queue depth, batch occupancy, per-stage latency.
//!
//! Load-generation and reporting live in
//! [`bench::serve_native`](crate::bench::serve_native).

pub mod gcn;
pub mod metrics;
pub mod registry;
pub mod server;

pub use gcn::{reference_forward, GcnForward, GcnModel};
pub use metrics::ServeMetrics;
pub use registry::{GraphHandle, GraphRegistry};
pub use server::{Payload, Request, Response, ServeConfig, Server};
