//! Native serving subsystem — multi-tenant GCN inference, end-to-end on
//! CPU, with no PJRT dependency.
//!
//! The [`coordinator`](crate::coordinator) routes requests through
//! compiled PJRT artifacts and therefore cannot execute anything when
//! the runtime is the offline stub. This layer is the other half of the
//! story: the **same** column-dimension batching (Accel-GCN §IV's
//! combined-warp insight lifted to whole requests, planned by the
//! shared [`ColumnBatcher`](crate::coordinator::ColumnBatcher) against
//! a *virtual* width ladder) executed through the PR-1 pipeline —
//! cached [`SpmmPlan`](crate::pipeline::SpmmPlan)s and the parallel
//! block-level executor — so `accel-gcn serve-native` serves real
//! traffic offline.
//!
//! * [`registry`] — multi-tenant graph residency: handles, relabeled
//!   adjacencies (DESIGN §2), ingress/egress permutations — now
//!   **epoch-versioned**: tenants evolve via edge-update batches, and
//!   each update swaps in an immutable next-epoch entry.
//! * [`gcn`] — the multi-layer forward stack ([`GcnForward`]): fused
//!   `SpMM → X·W + b → ReLU` per layer, chained in the relabeled
//!   domain with zero per-layer unpermutes.
//! * [`server`] — bounded queue + worker loop + batch fusion, plus the
//!   `UpdateGraph` request kind: updates apply after each round's
//!   compute groups, cached plans are *patched* (not rebuilt) via
//!   [`crate::delta`], in-flight requests finish on the epoch they
//!   captured at submit; see the module docs for the
//!   queue/worker/epoch semantics.
//! * [`metrics`] — queue depth, batch occupancy, per-stage latency,
//!   plan-swap count and patch latency — plus the robustness counters
//!   (shed updates, deadline drops, WAL appends/failures, snapshots).
//! * [`persist`] — the durability glue over [`crate::store`]: every
//!   `UpdateGraph` batch WAL-logged before it applies, commit seals
//!   after, periodic snapshot generations + WAL compaction, and
//!   [`Server::recover_tenants`] restoring every tenant (and
//!   pre-warming its plan) after a restart; see DESIGN §11.
//!
//! Load-generation and reporting live in
//! [`bench::serve_native`](crate::bench::serve_native); the dynamic
//! update path is measured by
//! [`bench::delta_update`](crate::bench::delta_update).

pub mod gcn;
pub mod metrics;
pub mod persist;
pub mod registry;
pub mod server;

pub use gcn::{reference_forward, GcnForward, GcnModel};
pub use metrics::ServeMetrics;
pub use persist::{PersistConfig, ServePersist};
pub use registry::{GraphEntry, GraphHandle, GraphRegistry, GraphUpdate};
pub use server::{
    Payload, RecoverySummary, Request, Response, ServeConfig, Server, SubmitError, UpdateReport,
};
