//! Multi-tenant graph residency: handles, relabeled adjacencies, and
//! the permutation metadata needed at the serving edge.
//!
//! A registered graph is preprocessed **once** into the relabeled domain
//! (DESIGN §2: rows *and* columns permuted ascending by degree,
//! `P·A·Pᵀ`). Requests enter in the original node order; the server
//! permutes feature rows at ingress, chains every layer in the relabeled
//! domain with zero per-layer unpermutes, and unpermutes once at egress.
//!
//! The registry deliberately does **not** own `SpmmPlan`s: plans live in
//! the server's bounded [`PlanCache`](crate::pipeline::PlanCache), so a
//! tenant that goes cold can have its partition evicted and rebuilt on
//! demand while its (smaller) CSR stays resident here.

use crate::graph::csr::Csr;
use crate::graph::degree::DegreeSorted;
use crate::pipeline::GraphFingerprint;
use anyhow::{anyhow, Result};
use std::sync::{Arc, Mutex};

/// Opaque ticket for a registered graph; cheap to copy into requests.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct GraphHandle(pub(crate) u32);

/// One resident graph: the relabeled adjacency plus edge permutations.
#[derive(Debug)]
pub struct GraphEntry {
    pub name: String,
    /// Node count (requests must carry `[n, c]` features).
    pub n: usize,
    /// `P·A·Pᵀ` — what the serving path executes. Its degree order is
    /// already ascending, so a plan built from it has an identity
    /// sort permutation and executes natively in this domain.
    pub relabeled: Arc<Csr>,
    /// Fingerprint of `relabeled`, hashed once at registration so the
    /// worker's per-round plan lookups skip the O(nnz) pass
    /// ([`PlanCache::plan_for_keyed`](crate::pipeline::PlanCache::plan_for_keyed)).
    pub fingerprint: GraphFingerprint,
    /// `perm[i]` = original row id of relabeled row `i`.
    pub perm: Vec<u32>,
}

impl GraphEntry {
    /// Ingress: reorder feature rows into the relabeled domain
    /// (`out[i] = x[perm[i]]`).
    pub fn permute_rows(&self, x: &[f32], f: usize) -> Vec<f32> {
        assert_eq!(x.len(), self.n * f, "feature shape mismatch");
        let mut out = vec![0f32; x.len()];
        for (i, &orig) in self.perm.iter().enumerate() {
            let o = orig as usize;
            out[i * f..(i + 1) * f].copy_from_slice(&x[o * f..(o + 1) * f]);
        }
        out
    }

    /// Egress: reorder result rows back to the original node order
    /// (`out[perm[i]] = y[i]`).
    pub fn unpermute_rows(&self, y: &[f32], f: usize) -> Vec<f32> {
        assert_eq!(y.len(), self.n * f, "result shape mismatch");
        let mut out = vec![0f32; y.len()];
        for (i, &orig) in self.perm.iter().enumerate() {
            let o = orig as usize;
            out[o * f..(o + 1) * f].copy_from_slice(&y[i * f..(i + 1) * f]);
        }
        out
    }
}

/// Handle-indexed table of resident graphs. Registration is rare and
/// mutex-guarded; lookups clone an `Arc`.
#[derive(Debug, Default)]
pub struct GraphRegistry {
    entries: Mutex<Vec<Arc<GraphEntry>>>,
}

impl GraphRegistry {
    pub fn new() -> GraphRegistry {
        GraphRegistry::default()
    }

    /// Preprocess `csr` into the relabeled domain and make it resident.
    /// Square adjacencies only (GCN propagation).
    pub fn register(&self, name: &str, csr: &Csr) -> Result<GraphHandle> {
        anyhow::ensure!(
            csr.n_rows == csr.n_cols,
            "adjacency must be square, got {}x{}",
            csr.n_rows,
            csr.n_cols
        );
        let sorted = DegreeSorted::new(csr);
        let relabeled = Arc::new(csr.relabel(&sorted.perm, &sorted.inv));
        let fingerprint = GraphFingerprint::of(&relabeled);
        let entry = Arc::new(GraphEntry {
            name: name.to_string(),
            n: csr.n_rows,
            relabeled,
            fingerprint,
            perm: sorted.perm,
        });
        let mut entries = self.entries.lock().unwrap();
        let handle = GraphHandle(entries.len() as u32);
        entries.push(entry);
        Ok(handle)
    }

    pub fn get(&self, handle: GraphHandle) -> Result<Arc<GraphEntry>> {
        self.entries
            .lock()
            .unwrap()
            .get(handle.0 as usize)
            .cloned()
            .ok_or_else(|| anyhow!("unknown graph handle {:?}", handle))
    }

    /// Number of resident graphs.
    pub fn len(&self) -> usize {
        self.entries.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg;

    fn random_csr(seed: u64, n: usize) -> Csr {
        let mut rng = Pcg::seed_from(seed);
        let mut edges = vec![(0u32, 0u32, 1.0f32)];
        for r in 0..n {
            for _ in 0..rng.range(0, 7) {
                edges.push((r as u32, rng.range(0, n) as u32, rng.f32() + 0.1));
            }
        }
        Csr::from_edges(n, n, &edges).unwrap()
    }

    #[test]
    fn register_and_lookup() {
        let reg = GraphRegistry::new();
        let a = reg.register("a", &random_csr(1, 20)).unwrap();
        let b = reg.register("b", &random_csr(2, 30)).unwrap();
        assert_ne!(a, b);
        assert_eq!(reg.len(), 2);
        assert_eq!(reg.get(a).unwrap().n, 20);
        assert_eq!(reg.get(b).unwrap().name, "b");
        assert!(reg.get(GraphHandle(7)).is_err());
    }

    #[test]
    fn rejects_rectangular() {
        let reg = GraphRegistry::new();
        let rect = Csr::from_edges(2, 3, &[(0, 2, 1.0)]).unwrap();
        assert!(reg.register("rect", &rect).is_err());
    }

    #[test]
    fn permute_unpermute_roundtrip() {
        let reg = GraphRegistry::new();
        let h = reg.register("g", &random_csr(3, 25)).unwrap();
        let e = reg.get(h).unwrap();
        let f = 3;
        let x: Vec<f32> = (0..25 * f).map(|i| i as f32).collect();
        let back = e.unpermute_rows(&e.permute_rows(&x, f), f);
        assert_eq!(back, x);
    }

    #[test]
    fn relabeled_degrees_ascend() {
        // the invariant the serve executor relies on: a plan built from
        // `relabeled` sorts with the identity permutation
        let reg = GraphRegistry::new();
        let h = reg.register("g", &random_csr(4, 40)).unwrap();
        let e = reg.get(h).unwrap();
        for r in 1..e.n {
            assert!(e.relabeled.degree(r - 1) <= e.relabeled.degree(r));
        }
    }
}
