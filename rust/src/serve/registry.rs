//! Multi-tenant graph residency: handles, relabeled adjacencies, the
//! permutation metadata needed at the serving edge — and, since the
//! delta subsystem, **epoch-versioned** tenant state.
//!
//! A registered graph is preprocessed **once** into the relabeled domain
//! (DESIGN §2: rows *and* columns permuted ascending by degree,
//! `P·A·Pᵀ`). Requests enter in the original node order; the server
//! permutes feature rows at ingress, chains every layer in the relabeled
//! domain with zero per-layer unpermutes, and unpermutes once at egress.
//!
//! ## Epochs
//!
//! Each tenant's visible state is one immutable [`GraphEntry`] behind a
//! briefly-held mutex; [`GraphRegistry::update`] applies an edge-update
//! batch to the tenant's [`DeltaGraph`], derives the next entry
//! (epoch + 1) with an *incremental* degree re-sort, and swaps the
//! `Arc` pointer. Readers never wait on update computation: the heavy
//! work happens under the per-tenant `delta` lock, the swap under the
//! `current` lock is a pointer store. A request that captured the old
//! `Arc` keeps executing against the old epoch — entries are immutable
//! and self-contained.
//!
//! The registry deliberately does **not** own `SpmmPlan`s: plans live in
//! the server's bounded [`PlanCache`](crate::pipeline::PlanCache), so a
//! tenant that goes cold can have its partition evicted and rebuilt on
//! demand while its (smaller) CSR stays resident here. Updates return
//! the old/new entry pair plus the [`RowChange`] set so the server can
//! patch the cached plan (see `server::apply_update`).

use crate::delta::{incremental_perm, invert_perm, DeltaGraph, EdgeUpdate, RowChange};
use crate::graph::csr::Csr;
use crate::graph::degree::DegreeSorted;
use crate::pipeline::GraphFingerprint;
use anyhow::{anyhow, Result};
use std::sync::{Arc, Mutex};

/// Opaque ticket for a registered graph; cheap to copy into requests.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct GraphHandle(pub(crate) u32);

/// One resident graph *version*: the relabeled adjacency plus edge
/// permutations, tagged with the epoch that produced it. Immutable —
/// updates produce a fresh entry and swap the tenant pointer.
#[derive(Debug)]
pub struct GraphEntry {
    pub name: String,
    /// Node count (requests must carry `[n, c]` features).
    pub n: usize,
    /// `P·A·Pᵀ` — what the serving path executes. Its degree order is
    /// already ascending, so a plan built from it has an identity
    /// sort permutation and executes natively in this domain.
    pub relabeled: Arc<Csr>,
    /// Fingerprint of `relabeled`, hashed once at registration so the
    /// worker's per-round plan lookups skip the O(nnz) pass
    /// ([`PlanCache::plan_for_keyed`](crate::pipeline::PlanCache::plan_for_keyed)).
    pub fingerprint: GraphFingerprint,
    /// `perm[i]` = original row id of relabeled row `i`.
    pub perm: Vec<u32>,
    /// `inv[orig]` = relabeled position of original row `orig`.
    pub inv: Vec<u32>,
    /// 0 at registration; +1 per applied update batch.
    pub epoch: u64,
}

impl GraphEntry {
    /// Ingress: reorder feature rows into the relabeled domain
    /// (`out[i] = x[perm[i]]`).
    pub fn permute_rows(&self, x: &[f32], f: usize) -> Vec<f32> {
        assert_eq!(x.len(), self.n * f, "feature shape mismatch");
        let mut out = vec![0f32; x.len()];
        for (i, &orig) in self.perm.iter().enumerate() {
            let o = orig as usize;
            out[i * f..(i + 1) * f].copy_from_slice(&x[o * f..(o + 1) * f]);
        }
        out
    }

    /// Egress: reorder result rows back to the original node order
    /// (`out[perm[i]] = y[i]`).
    pub fn unpermute_rows(&self, y: &[f32], f: usize) -> Vec<f32> {
        assert_eq!(y.len(), self.n * f, "result shape mismatch");
        let mut out = vec![0f32; y.len()];
        for (i, &orig) in self.perm.iter().enumerate() {
            let o = orig as usize;
            out[o * f..(o + 1) * f].copy_from_slice(&y[i * f..(i + 1) * f]);
        }
        out
    }
}

/// What one [`GraphRegistry::update`] produced — everything the server
/// needs to patch the cached plan and report the swap.
#[derive(Debug)]
pub struct GraphUpdate {
    /// The entry requests captured before the swap (old epoch).
    pub old: Arc<GraphEntry>,
    /// The freshly swapped-in entry (old epoch + 1).
    pub new: Arc<GraphEntry>,
    /// Rows whose adjacency changed, with old/new degrees (original
    /// node ids) — the input to plan patching.
    pub changes: Vec<RowChange>,
    /// Updates staged by the batch.
    pub staged_ops: usize,
    /// Whether the tenant's delta overlay crossed its compaction
    /// threshold and rewrote its base CSR.
    pub compacted: bool,
}

/// One tenant: the evolving original-domain graph plus the currently
/// visible entry. Two locks so readers never wait on update compute
/// (see module docs).
struct TenantState {
    name: String,
    /// Original-domain evolving graph; held for the whole update.
    delta: Mutex<DeltaGraph>,
    /// The visible entry; held only for pointer clone/store.
    current: Mutex<Arc<GraphEntry>>,
}

/// Handle-indexed table of resident graphs. Registration is rare and
/// mutex-guarded; lookups clone two `Arc`s.
#[derive(Default)]
pub struct GraphRegistry {
    entries: Mutex<Vec<Arc<TenantState>>>,
}

impl GraphRegistry {
    pub fn new() -> GraphRegistry {
        GraphRegistry::default()
    }

    /// Preprocess `csr` into the relabeled domain and make it resident
    /// at epoch 0. Square adjacencies only (GCN propagation).
    pub fn register(&self, name: &str, csr: &Csr) -> Result<GraphHandle> {
        self.register_at(name, csr, 0)
    }

    /// Registration seeded at a non-zero epoch — the recovery path:
    /// a tenant rebuilt from snapshot + WAL replay re-enters serving
    /// at the epoch it had reached before the crash, so subsequent
    /// updates (and their WAL records) continue the same chain.
    pub fn register_at(&self, name: &str, csr: &Csr, epoch: u64) -> Result<GraphHandle> {
        anyhow::ensure!(
            csr.n_rows == csr.n_cols,
            "adjacency must be square, got {}x{}",
            csr.n_rows,
            csr.n_cols
        );
        let sorted = DegreeSorted::new(csr);
        let relabeled = Arc::new(csr.relabel(&sorted.perm, &sorted.inv));
        let fingerprint = GraphFingerprint::of(&relabeled);
        let entry = Arc::new(GraphEntry {
            name: name.to_string(),
            n: csr.n_rows,
            relabeled,
            fingerprint,
            perm: sorted.perm,
            inv: sorted.inv,
            epoch,
        });
        let tenant = Arc::new(TenantState {
            name: name.to_string(),
            delta: Mutex::new(DeltaGraph::new(csr.clone())),
            current: Mutex::new(entry),
        });
        let mut entries = self.entries.lock().unwrap();
        let handle = GraphHandle(entries.len() as u32);
        entries.push(tenant);
        Ok(handle)
    }

    fn tenant(&self, handle: GraphHandle) -> Result<Arc<TenantState>> {
        self.entries
            .lock()
            .unwrap()
            .get(handle.0 as usize)
            .cloned()
            .ok_or_else(|| anyhow!("unknown graph handle {:?}", handle))
    }

    /// The tenant's currently visible entry.
    pub fn get(&self, handle: GraphHandle) -> Result<Arc<GraphEntry>> {
        let t = self.tenant(handle)?;
        let entry = t.current.lock().unwrap().clone();
        Ok(entry)
    }

    /// Apply an edge-update batch to a tenant and swap in the next
    /// epoch's entry. Concurrent updates to the same tenant serialize
    /// on its delta lock; readers only contend on the final pointer
    /// swap. Errors (out-of-bounds updates) leave the tenant untouched.
    pub fn update(&self, handle: GraphHandle, updates: &[EdgeUpdate]) -> Result<GraphUpdate> {
        let t = self.tenant(handle)?;
        let mut delta = t.delta.lock().unwrap();
        let old = t.current.lock().unwrap().clone();
        let report = delta.apply(updates)?;
        let new_csr = delta.snapshot();
        // incremental degree re-bucketing: only rows whose degree
        // changed move; the relabeled row structure doubles as the old
        // sorted row pointer
        let perm = incremental_perm(&old.perm, &old.relabeled.row_ptr, &report.changes);
        let inv = invert_perm(&perm);
        let relabeled = Arc::new(relabel_sorted(&new_csr, &perm, &inv));
        let fingerprint = GraphFingerprint::of(&relabeled);
        let entry = Arc::new(GraphEntry {
            name: t.name.clone(),
            n: old.n,
            relabeled,
            fingerprint,
            perm,
            inv,
            epoch: old.epoch + 1,
        });
        *t.current.lock().unwrap() = Arc::clone(&entry);
        Ok(GraphUpdate {
            old,
            new: entry,
            changes: report.changes,
            staged_ops: report.staged_ops,
            compacted: report.compacted,
        })
    }

    /// Look a tenant up by registry name (recovery resume / tooling;
    /// O(tenants), registration-order first match).
    pub fn find(&self, name: &str) -> Option<GraphHandle> {
        self.entries
            .lock()
            .unwrap()
            .iter()
            .position(|t| t.name == name)
            .map(|i| GraphHandle(i as u32))
    }

    /// The tenant's current original-domain effective adjacency — what
    /// a snapshot at the current epoch must contain. Materialized from
    /// the delta overlay; used by the periodic re-snapshot path.
    pub fn original_snapshot(&self, handle: GraphHandle) -> Result<Csr> {
        let t = self.tenant(handle)?;
        let delta = t.delta.lock().unwrap();
        Ok(delta.snapshot())
    }

    /// Number of resident graphs.
    pub fn len(&self) -> usize {
        self.entries.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl std::fmt::Debug for GraphRegistry {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("GraphRegistry").field("tenants", &self.len()).finish()
    }
}

/// `P·A·Pᵀ` given a known sort permutation: rows gathered through
/// `perm`, columns mapped through `inv`, each row re-sorted by its new
/// column ids only when the mapping disturbed its order. Equal to
/// [`Csr::relabel`] (the mapping is bijective, so no duplicates can
/// arise) without the full canonicalization pass.
fn relabel_sorted(csr: &Csr, perm: &[u32], inv: &[u32]) -> Csr {
    let n = csr.n_rows;
    let mut row_ptr = Vec::with_capacity(n + 1);
    let mut col_idx: Vec<u32> = Vec::with_capacity(csr.nnz());
    let mut vals: Vec<f32> = Vec::with_capacity(csr.nnz());
    row_ptr.push(0usize);
    let mut scratch: Vec<(u32, f32)> = Vec::new();
    for &src in perm {
        let start = col_idx.len();
        let mut ascending = true;
        for (c, v) in csr.row(src as usize) {
            let mapped = inv[c as usize];
            if ascending {
                if col_idx.len() > start && *col_idx.last().unwrap() > mapped {
                    ascending = false;
                } else {
                    col_idx.push(mapped);
                    vals.push(v);
                    continue;
                }
            }
            col_idx.push(mapped);
            vals.push(v);
        }
        if !ascending {
            scratch.clear();
            scratch.extend(col_idx[start..].iter().copied().zip(vals[start..].iter().copied()));
            scratch.sort_unstable_by_key(|&(c, _)| c);
            for (k, &(c, v)) in scratch.iter().enumerate() {
                col_idx[start + k] = c;
                vals[start + k] = v;
            }
        }
        row_ptr.push(col_idx.len());
    }
    Csr { n_rows: n, n_cols: csr.n_cols, row_ptr, col_idx, vals }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg;

    fn random_csr(seed: u64, n: usize) -> Csr {
        let mut rng = Pcg::seed_from(seed);
        let mut edges = vec![(0u32, 0u32, 1.0f32)];
        for r in 0..n {
            for _ in 0..rng.range(0, 7) {
                edges.push((r as u32, rng.range(0, n) as u32, rng.f32() + 0.1));
            }
        }
        Csr::from_edges(n, n, &edges).unwrap()
    }

    #[test]
    fn register_and_lookup() {
        let reg = GraphRegistry::new();
        let a = reg.register("a", &random_csr(1, 20)).unwrap();
        let b = reg.register("b", &random_csr(2, 30)).unwrap();
        assert_ne!(a, b);
        assert_eq!(reg.len(), 2);
        assert_eq!(reg.get(a).unwrap().n, 20);
        assert_eq!(reg.get(b).unwrap().name, "b");
        assert_eq!(reg.get(a).unwrap().epoch, 0);
        assert!(reg.get(GraphHandle(7)).is_err());
    }

    #[test]
    fn rejects_rectangular() {
        let reg = GraphRegistry::new();
        let rect = Csr::from_edges(2, 3, &[(0, 2, 1.0)]).unwrap();
        assert!(reg.register("rect", &rect).is_err());
    }

    #[test]
    fn permute_unpermute_roundtrip() {
        let reg = GraphRegistry::new();
        let h = reg.register("g", &random_csr(3, 25)).unwrap();
        let e = reg.get(h).unwrap();
        let f = 3;
        let x: Vec<f32> = (0..25 * f).map(|i| i as f32).collect();
        let back = e.unpermute_rows(&e.permute_rows(&x, f), f);
        assert_eq!(back, x);
        for (orig, &pos) in e.inv.iter().enumerate() {
            assert_eq!(e.perm[pos as usize] as usize, orig, "inv inverts perm");
        }
    }

    #[test]
    fn relabeled_degrees_ascend() {
        // the invariant the serve executor relies on: a plan built from
        // `relabeled` sorts with the identity permutation
        let reg = GraphRegistry::new();
        let h = reg.register("g", &random_csr(4, 40)).unwrap();
        let e = reg.get(h).unwrap();
        for r in 1..e.n {
            assert!(e.relabeled.degree(r - 1) <= e.relabeled.degree(r));
        }
    }

    #[test]
    fn update_bumps_epoch_and_matches_fresh_registration() {
        let reg = GraphRegistry::new();
        let base = random_csr(5, 35);
        let h = reg.register("g", &base).unwrap();
        let mut rng = Pcg::seed_from(17);
        let mut cur = base;
        for round in 1..=3u64 {
            let batch: Vec<EdgeUpdate> = (0..6)
                .map(|_| EdgeUpdate::Insert {
                    row: rng.range(0, 35) as u32,
                    col: rng.range(0, 35) as u32,
                    val: rng.f32() + 0.1,
                })
                .collect();
            let up = reg.update(h, &batch).unwrap();
            assert_eq!(up.new.epoch, round);
            assert_eq!(up.old.epoch, round - 1);
            assert_eq!(up.staged_ops, 6);
            // oracle: register the updated matrix fresh and compare
            let mut dg = crate::delta::DeltaGraph::new(cur.clone());
            dg.apply(&batch).unwrap();
            cur = dg.snapshot();
            let oracle = GraphRegistry::new();
            let oh = oracle.register("o", &cur).unwrap();
            let want = oracle.get(oh).unwrap();
            let got = reg.get(h).unwrap();
            assert_eq!(got.perm, want.perm, "incremental perm == fresh sort");
            assert_eq!(*got.relabeled, *want.relabeled, "relabeled matrices equal");
            assert_eq!(got.fingerprint, want.fingerprint);
        }
    }

    #[test]
    fn old_entry_survives_update_untouched() {
        let reg = GraphRegistry::new();
        let base = random_csr(6, 20);
        let h = reg.register("g", &base).unwrap();
        let old = reg.get(h).unwrap();
        let old_fp = old.fingerprint;
        reg.update(h, &[EdgeUpdate::Insert { row: 0, col: 19, val: 5.0 }]).unwrap();
        // the captured Arc still describes epoch 0
        assert_eq!(old.epoch, 0);
        assert_eq!(old.fingerprint, old_fp);
        let new = reg.get(h).unwrap();
        assert_eq!(new.epoch, 1);
        assert_ne!(new.fingerprint, old_fp, "topology change must re-fingerprint");
    }

    #[test]
    fn register_at_seeds_epoch_for_recovery() {
        let reg = GraphRegistry::new();
        let csr = random_csr(8, 15);
        let h = reg.register_at("g", &csr, 7).unwrap();
        assert_eq!(reg.get(h).unwrap().epoch, 7);
        assert_eq!(reg.find("g"), Some(h));
        assert_eq!(reg.find("nope"), None);
        assert_eq!(reg.original_snapshot(h).unwrap(), csr);
        let batch = vec![EdgeUpdate::Insert { row: 0, col: 9, val: 1.0 }];
        let up = reg.update(h, &batch).unwrap();
        assert_eq!(up.new.epoch, 8, "updates continue the recovered chain");
        let mut dg = crate::delta::DeltaGraph::new(csr);
        dg.apply(&batch).unwrap();
        assert_eq!(reg.original_snapshot(h).unwrap(), dg.snapshot());
    }

    #[test]
    fn update_rejects_out_of_bounds_and_keeps_epoch() {
        let reg = GraphRegistry::new();
        let h = reg.register("g", &random_csr(7, 10)).unwrap();
        let err = reg.update(h, &[EdgeUpdate::Insert { row: 99, col: 0, val: 1.0 }]);
        assert!(err.is_err());
        assert_eq!(reg.get(h).unwrap().epoch, 0, "failed update swaps nothing");
        assert!(reg.update(GraphHandle(9), &[]).is_err(), "unknown handle");
    }
}
