//! The native serving engine: bounded request queue + worker loop over
//! the parallel SpMM pipeline. No PJRT, no compiled artifacts — the
//! whole request path executes on CPU through the cached
//! [`SpmmPlan`](crate::pipeline::SpmmPlan) and the block-level parallel
//! executor.
//!
//! ## Queue / worker semantics
//!
//! * [`Server::submit`] validates the request against the resident
//!   graph, then enqueues it if the bounded queue has room (a full
//!   queue rejects immediately — back-pressure instead of unbounded
//!   buffering) and returns a per-request reply channel.
//! * One worker thread drains **everything** pending per round, groups
//!   requests by `(graph, epoch, model)`, plans column fusion per group
//!   with the shared [`ColumnBatcher`] against the configured virtual
//!   width ladder, executes each fused batch, splits, and replies.
//!   Requests that arrive while a round is executing coalesce into the
//!   next round — exactly how load spikes turn into wider (cheaper per
//!   request) batches.
//! * Plans come from a **bounded** [`PlanCache`] (LRU), so many graphs
//!   can be resident with preprocessing memory capped; evicted tenants
//!   rebuild on their next batch.
//! * Shutdown (drop) is graceful: the worker drains what is queued,
//!   replies, then exits.
//!
//! ## Epochs and the `UpdateGraph` request kind
//!
//! [`Server::submit_update`] enqueues a batch of
//! [`EdgeUpdate`]s against a tenant. The worker applies updates at the
//! **end** of each round, after the round's compute groups: the
//! registry swaps in an epoch+1 [`GraphEntry`] (atomic pointer swap —
//! submitters never wait on update compute), and the cached plan is
//! *patched* via [`patch_identity_plan`] + [`PlanCache::refresh`]
//! instead of rebuilt. Compute requests capture their tenant's entry
//! `Arc` **at submit**, so anything already queued — in flight —
//! finishes on the epoch it saw (and, having run before the swap, on
//! the still-cached plan), while requests submitted after the update's
//! reply pick up the patched plan. Mixed-epoch requests in one round
//! simply land in different fusion groups.
//!
//! ## Domains
//!
//! Everything between ingress and egress runs in the relabeled domain
//! (DESIGN §2): fusion permutes feature rows while copying members into
//! the fused matrix, layers chain with zero per-layer unpermutes, and
//! the split back to per-request tensors unpermutes while copying out.

use super::gcn::{GcnForward, GcnModel};
use super::metrics::ServeMetrics;
use super::persist::{PersistConfig, ServePersist};
use super::registry::{GraphEntry, GraphHandle, GraphRegistry};
use crate::coordinator::ColumnBatcher;
use crate::delta::{patch_identity_plan, EdgeUpdate};
use crate::graph::csr::Csr;
use crate::partition::patterns::PartitionParams;
use crate::pipeline::{GraphFingerprint, GraphKey, PlanCache};
use crate::runtime::HostTensor;
use crate::store::{recover_tenant, StoreError};
use crate::util::threadpool::ThreadPool;
use anyhow::{anyhow, Result};
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Native-serving configuration (the ladder is virtual: plain widths,
/// no compiled artifacts behind them).
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Workers in the SpMM/dense execution pool.
    pub threads: usize,
    /// Pending-request bound; submits beyond it are rejected.
    pub queue_capacity: usize,
    /// Virtual width ladder (ascending after validation); the widest
    /// rung caps fused batch width.
    pub ladder: Vec<usize>,
    /// Partition tunables for plans built on behalf of tenants.
    pub params: PartitionParams,
    /// Max resident `SpmmPlan`s (LRU-evicted beyond this).
    pub plan_capacity: usize,
    /// Run the [`PlanTuner`](crate::tune::PlanTuner) over every
    /// resident plan after this many worker rounds (0 = tuning off).
    /// Effective only while the global observability registry is
    /// enabled — the tuner consumes its per-shard timeline.
    pub tune_every: usize,
    /// Durability: snapshot + WAL persistence under a data directory
    /// (DESIGN §11). `None` = fully in-memory serving (the default).
    pub persist: Option<PersistConfig>,
    /// Default compute-request deadline applied by [`Server::submit`];
    /// `None` = no deadline. Admission rejects a request whose
    /// predicted queue wait (EWMA of recent waits) already exceeds the
    /// budget; the worker drops (with a typed reply) requests that
    /// expired while queued.
    pub deadline: Option<Duration>,
}

impl Default for ServeConfig {
    fn default() -> ServeConfig {
        ServeConfig {
            threads: 4,
            queue_capacity: 1024,
            ladder: vec![32, 64, 128],
            params: PartitionParams::default(),
            plan_capacity: 8,
            tune_every: 0,
            persist: None,
            deadline: None,
        }
    }
}

/// What a request asks the server to compute.
#[derive(Clone, Debug)]
pub enum Payload {
    /// `Y = Â·X` (one SpMM against the tenant's adjacency).
    Spmm { x: HostTensor },
    /// Full multi-layer GCN forward pass under `model`.
    Gcn { model: Arc<GcnModel>, x: HostTensor },
}

/// A queued inference request against a resident graph.
#[derive(Clone, Debug)]
pub struct Request {
    pub graph: GraphHandle,
    pub payload: Payload,
}

/// A completed request: result rows in the **original** node order.
#[derive(Clone, Debug)]
pub struct Response {
    pub y: HostTensor,
}

/// Reply to an `UpdateGraph` request: what the swap did.
#[derive(Clone, Copy, Debug)]
pub struct UpdateReport {
    /// The tenant's epoch after the swap.
    pub epoch: u64,
    /// Rows whose adjacency changed.
    pub rows_changed: usize,
    /// Edge updates staged by the batch.
    pub staged_ops: usize,
    /// Whether the tenant's overlay compacted its base CSR.
    pub compacted: bool,
    /// True if a resident plan was patched in place
    /// ([`PlanCache::refresh`]); false if no plan was resident (the
    /// next request builds from the new matrix).
    pub plan_patched: bool,
    /// Registry update + plan patch time, seconds.
    pub patch_secs: f64,
}

/// Why a submission was refused — typed so callers can tell transient
/// back-pressure (retry with backoff, shed under overload) from a
/// request that will never be accepted.
#[derive(Clone, Debug, PartialEq)]
pub enum SubmitError {
    /// The bounded queue is at capacity. Transient: retry after
    /// backoff, or shed. Carries the observed depth so clients can
    /// scale their backoff to the backlog.
    Backpressure { depth: usize, capacity: usize },
    /// The request cannot (admission: predicted from the queue-wait
    /// EWMA) or did not (worker pickup) meet its deadline. `wait` is
    /// the predicted or actual queue wait, `depth` the backlog at
    /// rejection time.
    Deadline { wait: Duration, depth: usize },
    /// The server is shutting down; no further work is accepted.
    ShuttingDown,
    /// The worker thread is not running (it panicked or was never
    /// started) — accepted requests would never be served.
    WorkerDead,
    /// The request itself is malformed (shape, width, unknown handle,
    /// out-of-bounds update). Never retryable.
    Invalid(String),
}

impl SubmitError {
    /// True for failures a client may retry after backing off
    /// (back-pressure); false for permanent ones.
    pub fn is_retryable(&self) -> bool {
        matches!(self, SubmitError::Backpressure { .. })
    }
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::Backpressure { depth, capacity } => {
                write!(f, "queue full ({depth} pending, capacity {capacity})")
            }
            SubmitError::Deadline { wait, depth } => write!(
                f,
                "deadline unmet (queue wait {:.1}ms, {depth} pending)",
                wait.as_secs_f64() * 1e3
            ),
            SubmitError::ShuttingDown => write!(f, "server is shutting down"),
            SubmitError::WorkerDead => write!(f, "serve worker is not running"),
            SubmitError::Invalid(msg) => write!(f, "{msg}"),
        }
    }
}

impl std::error::Error for SubmitError {}

/// What [`Server::recover_tenants`] rebuilt for one tenant — the
/// restart-side mirror of [`UpdateReport`].
#[derive(Clone, Debug)]
pub struct RecoverySummary {
    /// Registry name (from the snapshot header).
    pub name: String,
    /// The handle the tenant re-entered serving under.
    pub handle: GraphHandle,
    /// Epoch after snapshot + WAL replay.
    pub epoch: u64,
    /// Epoch of the snapshot generation replay started from.
    pub snapshot_epoch: u64,
    /// Which snapshot generation loaded.
    pub snapshot_gen: u64,
    /// True if the newest generation was unreadable and recovery fell
    /// back to an older one.
    pub snapshot_fell_back: bool,
    /// WAL batch records replayed on top of the snapshot.
    pub replayed_batches: usize,
    /// True if a torn final WAL record was dropped.
    pub torn_tail_dropped: bool,
    /// True when every replayed epoch matched its commit seal (false =
    /// the final batch had no seal; it is applied but unverified).
    pub fingerprint_verified: bool,
    /// Fingerprint of the recovered relabeled matrix — the plan-cache
    /// key, asserted equal to the store's recovered fingerprint.
    pub fingerprint: GraphFingerprint,
}

struct ComputePending {
    graph: GraphHandle,
    /// The tenant entry captured at submit — this request's epoch.
    entry: Arc<GraphEntry>,
    payload: Payload,
    reply: Sender<Result<Response>>,
    enqueued: Instant,
    /// Absolute expiry; the worker sheds the request (typed reply) if
    /// it picks it up past this instant.
    deadline: Option<Instant>,
    /// Per-request trace id
    /// ([`Registry::next_trace_id`](crate::obs::Registry::next_trace_id));
    /// 0 when the registry was disabled at submit (untraced).
    trace: u64,
    /// Wall-clock enqueue stamp against the process trace epoch; 0 when
    /// untraced.
    enqueued_ns: u64,
}

struct UpdatePending {
    graph: GraphHandle,
    updates: Vec<EdgeUpdate>,
    reply: Sender<Result<UpdateReport>>,
    enqueued: Instant,
}

/// The queue's request kinds: compute (SpMM / GCN) and graph updates.
enum QueuedRequest {
    Compute(ComputePending),
    UpdateGraph(UpdatePending),
}

impl QueuedRequest {
    fn enqueued(&self) -> Instant {
        match self {
            QueuedRequest::Compute(p) => p.enqueued,
            QueuedRequest::UpdateGraph(p) => p.enqueued,
        }
    }
}

struct QueueState {
    pending: Vec<QueuedRequest>,
    paused: bool,
    shutdown: bool,
}

struct SharedQueue {
    state: Mutex<QueueState>,
    cv: Condvar,
    /// EWMA of submit → pickup wait in nanoseconds (α = 1/4), updated
    /// by the worker at pickup and read lock-free by deadline
    /// admission. 0 until the first request is picked up.
    ewma_wait_ns: AtomicU64,
}

/// Handle to the native serving engine; dropping it shuts the worker
/// down gracefully (queued requests are still served).
pub struct Server {
    registry: Arc<GraphRegistry>,
    shared: Arc<SharedQueue>,
    metrics: Arc<ServeMetrics>,
    /// Shared with the worker: updates patch plans in place, the worker
    /// reads them per round.
    cache: Arc<PlanCache>,
    queue_capacity: usize,
    max_width: usize,
    /// Partition tunables, kept for recovery-time plan pre-warm (plans
    /// in the worker path are built with the same params).
    params: PartitionParams,
    /// Default deadline applied to [`Server::submit`] (see
    /// [`ServeConfig::deadline`]).
    default_deadline: Option<Duration>,
    /// Durability glue; `None` = in-memory serving.
    persist: Option<Arc<ServePersist>>,
    worker: Option<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Validate the config and start the worker loop. With
    /// [`ServeConfig::persist`] set, the data directory is opened (and
    /// created) here; call [`Server::recover_tenants`] before
    /// registering anything if it may already hold state.
    pub fn start(config: ServeConfig) -> Result<Server> {
        let batcher = ColumnBatcher::from_widths(&config.ladder)?;
        anyhow::ensure!(config.queue_capacity > 0, "queue capacity must be positive");
        let persist = match &config.persist {
            Some(pc) => Some(Arc::new(ServePersist::open(pc)?)),
            None => None,
        };
        let mut server = Server::front_end(&batcher, &config);
        server.persist = persist.clone();
        let shared = Arc::clone(&server.shared);
        let registry = Arc::clone(&server.registry);
        let metrics = Arc::clone(&server.metrics);
        let cache = Arc::clone(&server.cache);
        let worker = std::thread::Builder::new()
            .name("accel-gcn-serve".into())
            .spawn(move || {
                let pool = ThreadPool::new(config.threads);
                worker_loop(
                    shared,
                    registry,
                    metrics,
                    batcher,
                    pool,
                    cache,
                    config.params,
                    config.tune_every,
                    persist,
                );
            })
            .expect("spawn serve worker");
        server.worker = Some(worker);
        Ok(server)
    }

    /// The front-end half alone (no worker thread) — used by tests that
    /// need deterministic queue states.
    fn front_end(batcher: &ColumnBatcher, config: &ServeConfig) -> Server {
        Server {
            registry: Arc::new(GraphRegistry::new()),
            shared: Arc::new(SharedQueue {
                state: Mutex::new(QueueState {
                    pending: Vec::new(),
                    paused: false,
                    shutdown: false,
                }),
                cv: Condvar::new(),
                ewma_wait_ns: AtomicU64::new(0),
            }),
            metrics: Arc::new(ServeMetrics::new()),
            cache: Arc::new(PlanCache::bounded(config.plan_capacity)),
            queue_capacity: config.queue_capacity,
            max_width: batcher.max_width,
            params: config.params,
            default_deadline: config.deadline,
            persist: None,
            worker: None,
        }
    }

    #[cfg(test)]
    fn start_without_worker(config: ServeConfig) -> Result<Server> {
        let batcher = ColumnBatcher::from_widths(&config.ladder)?;
        anyhow::ensure!(config.queue_capacity > 0, "queue capacity must be positive");
        Ok(Server::front_end(&batcher, &config))
    }

    /// Make a graph resident and get its handle. Under persistence the
    /// tenant's epoch-0 snapshot is written (and its WAL opened)
    /// *before* the handle is returned — a registered tenant is always
    /// recoverable. Refuses (typed [`StoreError::TenantExists`]) when
    /// the data directory already holds state for `name`: recover it
    /// instead of forking its history.
    pub fn register_graph(&self, name: &str, csr: &Csr) -> Result<GraphHandle> {
        if let Some(p) = &self.persist {
            if p.tenant_exists(name)? {
                return Err(StoreError::TenantExists {
                    dir: p.store().tenant(name)?.dir().to_path_buf(),
                }
                .into());
            }
        }
        let handle = self.registry.register(name, csr)?;
        if let Some(p) = &self.persist {
            let entry = self.registry.get(handle)?;
            p.attach_new(handle, &entry, csr)?;
        }
        Ok(handle)
    }

    /// Rebuild every tenant found under the data directory: snapshot +
    /// WAL tail replayed through the same
    /// [`DeltaGraph::apply`](crate::delta::DeltaGraph::apply) path live
    /// updates take, re-registered at its recovered epoch, its
    /// [`SpmmPlan`](crate::pipeline::SpmmPlan) pre-warmed into the
    /// cache, and its WAL re-opened for appends. The recovered
    /// relabeled fingerprint (the plan-cache key) is asserted against
    /// both the store's replay result and the re-registered entry —
    /// divergence is a typed [`StoreError::FingerprintMismatch`], not a
    /// silently different plan.
    pub fn recover_tenants(&self) -> Result<Vec<RecoverySummary>> {
        let Some(p) = &self.persist else {
            return Ok(Vec::new());
        };
        let mut out = Vec::new();
        for dir in p.store().tenant_dirs()? {
            let ts = p.store().tenant_by_dir(&dir);
            let rec = recover_tenant(&ts)?;
            let handle = self.registry.register_at(&rec.name, &rec.csr, rec.epoch)?;
            let entry = self.registry.get(handle)?;
            if entry.fingerprint != rec.fingerprint {
                return Err(StoreError::FingerprintMismatch {
                    tenant: rec.name.clone(),
                    epoch: rec.epoch,
                    detail: format!(
                        "re-registered entry fingerprints {:?}, recovery produced {:?}",
                        entry.fingerprint, rec.fingerprint
                    ),
                }
                .into());
            }
            // pre-warm: the first post-restart batch must not pay the
            // from-scratch partition build
            let _ = self.cache.plan_for_keyed(entry.fingerprint, &entry.relabeled, self.params);
            p.attach_recovered(handle, &dir)?;
            self.metrics.epoch.set_max(rec.epoch as i64);
            out.push(RecoverySummary {
                name: rec.name,
                handle,
                epoch: rec.epoch,
                snapshot_epoch: rec.snapshot_epoch,
                snapshot_gen: rec.snapshot_gen,
                snapshot_fell_back: rec.snapshot_fell_back,
                replayed_batches: rec.replayed_batches,
                torn_tail_dropped: rec.torn_tail_dropped,
                fingerprint_verified: rec.fingerprint_verified,
                fingerprint: rec.fingerprint,
            });
        }
        Ok(out)
    }

    /// The durability glue, when persistence is configured.
    pub fn persist(&self) -> Option<&Arc<ServePersist>> {
        self.persist.as_ref()
    }

    pub fn metrics(&self) -> &Arc<ServeMetrics> {
        &self.metrics
    }

    /// The server's plan cache (shared with the worker).
    pub fn plan_cache(&self) -> &Arc<PlanCache> {
        &self.cache
    }

    /// Widest fused batch the ladder supports.
    pub fn max_width(&self) -> usize {
        self.max_width
    }

    /// Resident graph count.
    pub fn resident_graphs(&self) -> usize {
        self.registry.len()
    }

    /// A tenant's current epoch.
    pub fn graph_epoch(&self, graph: GraphHandle) -> Result<u64> {
        Ok(self.registry.get(graph)?.epoch)
    }

    /// The tenant's current original-domain adjacency (base CSR with
    /// every applied update folded in). Used by the bench harness and
    /// recovery checks as the verification oracle after a resume, when
    /// the caller cannot regenerate the graph from a seed.
    pub fn graph_snapshot(&self, graph: GraphHandle) -> Result<Csr> {
        self.registry.original_snapshot(graph)
    }

    /// Hold the worker between rounds: submissions keep queueing (and
    /// will fuse into one wide round on [`Server::resume`]), nothing
    /// executes. Shutdown overrides a pause — queued work still drains.
    pub fn pause(&self) {
        self.shared.state.lock().unwrap().paused = true;
    }

    /// Release a [`Server::pause`]; the worker drains the backlog as
    /// one round.
    pub fn resume(&self) {
        self.shared.state.lock().unwrap().paused = false;
        self.shared.cv.notify_all();
    }

    fn enqueue(&self, req: QueuedRequest) -> Result<(), SubmitError> {
        {
            let mut st = self.shared.state.lock().unwrap();
            if st.shutdown {
                self.metrics.rejected.inc();
                return Err(SubmitError::ShuttingDown);
            }
            if st.pending.len() >= self.queue_capacity {
                self.metrics.rejected.inc();
                return Err(SubmitError::Backpressure {
                    depth: st.pending.len(),
                    capacity: self.queue_capacity,
                });
            }
            st.pending.push(req);
            self.metrics.queue_depth.set(st.pending.len() as i64);
        }
        self.metrics.submitted.inc();
        self.shared.cv.notify_one();
        Ok(())
    }

    /// Validate and enqueue; returns the reply channel. Errors on shape
    /// mismatch, widths the ladder cannot carry, a full queue, or a
    /// server that is shutting down. Typed-error variant of
    /// [`Server::submit`] (applies the configured default deadline).
    pub fn try_submit(&self, req: Request) -> Result<Receiver<Result<Response>>, SubmitError> {
        self.try_submit_inner(req, self.default_deadline)
    }

    /// [`Server::try_submit`] with an explicit per-request deadline
    /// budget (overrides [`ServeConfig::deadline`]).
    pub fn try_submit_with_deadline(
        &self,
        req: Request,
        budget: Duration,
    ) -> Result<Receiver<Result<Response>>, SubmitError> {
        self.try_submit_inner(req, Some(budget))
    }

    fn try_submit_inner(
        &self,
        req: Request,
        budget: Option<Duration>,
    ) -> Result<Receiver<Result<Response>>, SubmitError> {
        // a dead worker (e.g. a panic in a batch) must not silently
        // accept requests that will never be served
        if self.worker.as_ref().is_some_and(|h| h.is_finished()) {
            self.metrics.rejected.inc();
            return Err(SubmitError::WorkerDead);
        }
        let entry = match self.registry.get(req.graph) {
            Ok(e) => e,
            // unknown handle precedes validation: not counted as a
            // rejection (matches the pre-typed-error behavior)
            Err(e) => return Err(SubmitError::Invalid(e.to_string())),
        };
        if let Err(e) = self.validate(&entry, &req.payload) {
            self.metrics.rejected.inc();
            return Err(SubmitError::Invalid(format!("{e:#}")));
        }
        // deadline admission: if recent requests waited longer than
        // this one's whole budget, it would expire in the queue —
        // reject at the door instead of queueing doomed work
        let deadline = match budget {
            None => None,
            Some(b) => {
                let predicted =
                    Duration::from_nanos(self.shared.ewma_wait_ns.load(Ordering::Relaxed));
                if predicted > b {
                    let depth = self.shared.state.lock().unwrap().pending.len();
                    self.metrics.rejected.inc();
                    self.metrics.deadline_expired.inc();
                    return Err(SubmitError::Deadline { wait: predicted, depth });
                }
                Some(Instant::now() + b)
            }
        };
        let (reply, rx) = channel();
        // allocate the request's trace identity at the door: every span
        // the request touches downstream carries this id in its args
        let reg = crate::obs::Registry::global();
        let (trace, enqueued_ns) =
            if reg.enabled() { (reg.next_trace_id(), crate::obs::epoch_now_ns()) } else { (0, 0) };
        self.enqueue(QueuedRequest::Compute(ComputePending {
            graph: req.graph,
            entry,
            payload: req.payload,
            reply,
            enqueued: Instant::now(),
            deadline,
            trace,
            enqueued_ns,
        }))?;
        Ok(rx)
    }

    /// [`Server::try_submit`] with the typed error erased into
    /// `anyhow` (messages unchanged — "queue full (…)" etc.).
    pub fn submit(&self, req: Request) -> Result<Receiver<Result<Response>>> {
        self.try_submit(req).map_err(anyhow::Error::new)
    }

    /// Enqueue an `UpdateGraph` request: apply `updates` to the tenant
    /// and swap in the next epoch. Ordering guarantee: compute requests
    /// submitted *before* this call execute against the pre-update
    /// epoch, ones submitted after the reply observe the new epoch.
    /// Updates take no deadline — once logged they are authoritative.
    pub fn try_submit_update(
        &self,
        graph: GraphHandle,
        updates: Vec<EdgeUpdate>,
    ) -> Result<Receiver<Result<UpdateReport>>, SubmitError> {
        if self.worker.as_ref().is_some_and(|h| h.is_finished()) {
            self.metrics.rejected.inc();
            return Err(SubmitError::WorkerDead);
        }
        let entry = match self.registry.get(graph) {
            Ok(e) => e,
            Err(e) => return Err(SubmitError::Invalid(e.to_string())),
        };
        for u in &updates {
            let (r, c) = (u.row() as usize, u.col() as usize);
            if r >= entry.n || c >= entry.n {
                self.metrics.rejected.inc();
                return Err(SubmitError::Invalid(format!(
                    "update ({r},{c}) out of bounds for {}-node tenant",
                    entry.n
                )));
            }
        }
        let (reply, rx) = channel();
        self.enqueue(QueuedRequest::UpdateGraph(UpdatePending {
            graph,
            updates,
            reply,
            enqueued: Instant::now(),
        }))?;
        Ok(rx)
    }

    /// [`Server::try_submit_update`] with the typed error erased into
    /// `anyhow`.
    pub fn submit_update(
        &self,
        graph: GraphHandle,
        updates: Vec<EdgeUpdate>,
    ) -> Result<Receiver<Result<UpdateReport>>> {
        self.try_submit_update(graph, updates).map_err(anyhow::Error::new)
    }

    /// [`Server::submit_update`] + wait for the swap to complete.
    pub fn update_graph(&self, graph: GraphHandle, updates: Vec<EdgeUpdate>) -> Result<UpdateReport> {
        let rx = self.submit_update(graph, updates)?;
        rx.recv().map_err(|_| anyhow!("server dropped the update reply"))?
    }

    /// Convenience: submit a single SpMM request.
    pub fn submit_spmm(&self, graph: GraphHandle, x: HostTensor) -> Result<Receiver<Result<Response>>> {
        self.submit(Request { graph, payload: Payload::Spmm { x } })
    }

    /// Convenience: submit a GCN forward-pass request.
    pub fn submit_gcn(
        &self,
        graph: GraphHandle,
        model: Arc<GcnModel>,
        x: HostTensor,
    ) -> Result<Receiver<Result<Response>>> {
        self.submit(Request { graph, payload: Payload::Gcn { model, x } })
    }

    fn validate(&self, entry: &GraphEntry, payload: &Payload) -> Result<()> {
        let x = match payload {
            Payload::Spmm { x } | Payload::Gcn { x, .. } => x,
        };
        anyhow::ensure!(
            x.shape().len() == 2 && x.shape()[0] == entry.n,
            "features must be [{} × c], got {:?}",
            entry.n,
            x.shape()
        );
        anyhow::ensure!(x.as_f32().is_ok(), "features must be f32");
        let w = x.shape()[1];
        match payload {
            Payload::Spmm { .. } => {
                anyhow::ensure!(
                    w > 0 && w <= self.max_width,
                    "request width {w} outside ladder (max {})",
                    self.max_width
                );
            }
            Payload::Gcn { model, .. } => {
                anyhow::ensure!(
                    w == model.config.in_dim,
                    "GCN features must be [n × in_dim={}], got width {w}",
                    model.config.in_dim
                );
                anyhow::ensure!(
                    model.max_width() > 0 && model.max_width() <= self.max_width,
                    "model width {} exceeds ladder max {}",
                    model.max_width(),
                    self.max_width
                );
                // fields are public: reject parameter/config mismatches
                // here, where they can error, instead of panicking (and
                // killing) the worker thread mid-batch
                let dims = model.dims();
                anyhow::ensure!(
                    model.weights.len() == dims.len() && model.biases.len() == dims.len(),
                    "model has {} weight / {} bias layers, config declares {}",
                    model.weights.len(),
                    model.biases.len(),
                    dims.len()
                );
                for (l, &(din, dout)) in dims.iter().enumerate() {
                    anyhow::ensure!(
                        model.weights[l].len() == din * dout && model.biases[l].len() == dout,
                        "layer {l} parameters are not [{din}×{dout}] + [{dout}]"
                    );
                }
            }
        }
        Ok(())
    }
}

impl Server {
    /// Graceful shutdown with the ordering durability requires:
    /// **(1) stop admissions and wake the worker** (the shutdown flag
    /// overrides a pause), **(2) join the worker**, which drains every
    /// queued request/update and replies — so WAL appends for queued
    /// updates all happen-before **(3) the final WAL flush**. Safe to
    /// call mid-round and more than once; `Drop` delegates here.
    pub fn shutdown(&mut self) {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.shutdown = true;
        }
        self.shared.cv.notify_all();
        if let Some(h) = self.worker.take() {
            let _ = h.join();
        }
        if let Some(p) = &self.persist {
            if let Err(e) = p.flush_all() {
                eprintln!("[store] final WAL flush failed: {e}");
            }
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shutdown();
    }
}

// ---------------------------------------------------------------------
// worker side

#[allow(clippy::too_many_arguments)]
fn worker_loop(
    shared: Arc<SharedQueue>,
    registry: Arc<GraphRegistry>,
    metrics: Arc<ServeMetrics>,
    batcher: ColumnBatcher,
    pool: ThreadPool,
    cache: Arc<PlanCache>,
    params: PartitionParams,
    tune_every: usize,
    persist: Option<Arc<ServePersist>>,
) {
    let mut rounds: usize = 0;
    loop {
        let round: Vec<QueuedRequest> = {
            let mut st = shared.state.lock().unwrap();
            while (st.pending.is_empty() || st.paused) && !st.shutdown {
                st = shared.cv.wait(st).unwrap();
            }
            if st.pending.is_empty() {
                return; // shutdown with an empty queue
            }
            let drained = std::mem::take(&mut st.pending);
            metrics.queue_depth.set(0);
            drained
        };
        let picked_up = Instant::now();
        let reg = crate::obs::Registry::global();
        for p in &round {
            let wait = picked_up.duration_since(p.enqueued());
            metrics.queue_wait.record(wait.as_secs_f64());
            // feed deadline admission: EWMA with α = 1/4, lock-free
            let w = wait.as_nanos() as u64;
            let old = shared.ewma_wait_ns.load(Ordering::Relaxed);
            let ewma = if old == 0 { w } else { old - old / 4 + w / 4 };
            shared.ewma_wait_ns.store(ewma, Ordering::Relaxed);
            // queue wait spans submit → pickup across threads, so it is
            // recorded by path rather than by guard (self-gating when
            // the registry is disabled); traced requests additionally
            // land on the timeline with their begin at enqueue
            match p {
                QueuedRequest::Compute(c) if c.enqueued_ns != 0 => {
                    let mut args = crate::util::json::Json::obj();
                    args.set("trace", c.trace);
                    reg.record_span_interval(
                        "serve_round/queue_wait",
                        c.enqueued_ns,
                        wait.as_nanos() as u64,
                        Some(args),
                    );
                }
                _ => reg.record_span_ns("serve_round/queue_wait", wait.as_nanos() as u64),
            }
        }
        // compute groups run first, updates apply at round end: every
        // compute request executes against the entry it captured at
        // submit (its epoch), so serving them before the swap lets
        // old-epoch groups hit the still-cached plan — which the update
        // then patches in place, instead of the update dropping the key
        // and forcing a from-scratch rebuild of the *old* topology for
        // requests already in the round
        let mut spmm_groups: BTreeMap<(GraphHandle, u64), Vec<ComputePending>> = BTreeMap::new();
        let mut gcn_groups: BTreeMap<(GraphHandle, u64, usize), Vec<ComputePending>> =
            BTreeMap::new();
        let mut updates: Vec<UpdatePending> = Vec::new();
        for q in round {
            match q {
                QueuedRequest::UpdateGraph(u) => updates.push(u),
                QueuedRequest::Compute(p) => {
                    // a request that expired while queued is shed here
                    // with a typed reply — executing it would waste a
                    // batch slot on an answer the client gave up on
                    if let Some(d) = p.deadline {
                        if picked_up > d {
                            metrics.deadline_expired.inc();
                            metrics.errors.inc();
                            let wait = picked_up.duration_since(p.enqueued);
                            metrics.total.record(p.enqueued.elapsed().as_secs_f64());
                            let _ = p.reply.send(Err(anyhow::Error::new(
                                SubmitError::Deadline { wait, depth: 0 },
                            )));
                            continue;
                        }
                    }
                    match &p.payload {
                        Payload::Spmm { .. } => {
                            spmm_groups.entry((p.graph, p.entry.epoch)).or_default().push(p)
                        }
                        Payload::Gcn { model, .. } => {
                            let key = (p.graph, p.entry.epoch, Arc::as_ptr(model) as usize);
                            gcn_groups.entry(key).or_default().push(p)
                        }
                    }
                }
            }
        }
        for (_, group) in spmm_groups {
            run_spmm_group(group, &metrics, &batcher, &pool, &cache, params);
        }
        for (_, group) in gcn_groups {
            run_gcn_group(group, &metrics, &batcher, &pool, &cache, params);
        }
        for u in updates {
            apply_update(u, &registry, &metrics, &cache, params, persist.as_deref());
        }
        rounds += 1;
        if tune_every > 0 && rounds % tune_every == 0 {
            tune_resident_plans(&cache, pool.size());
        }
    }
}

/// One closed-loop tuning pass over every resident plan: fit the cost
/// model to the registry's per-shard aggregates, re-cut where the
/// predicted imbalance improves, and swap tuned plans in place under
/// their unchanged cache keys ([`PlanCache::refresh`] with the same
/// fingerprint). Swaps count on the `tune.swaps` registry counter —
/// deliberately separate from `ServeMetrics::plan_swaps`, which counts
/// *topology* (epoch) swaps. After any swap the shard aggregates are
/// reset so the next warmup window measures only the new layout.
fn tune_resident_plans(cache: &PlanCache, n_shards: usize) {
    let reg = crate::obs::Registry::global();
    if !reg.enabled() {
        return;
    }
    let tuner = crate::tune::PlanTuner::default();
    let mut swapped = false;
    for (key, plan) in cache.entries() {
        if let Some(tuned) = tuner.maybe_tune(reg, &plan, n_shards) {
            cache.refresh(&key, Arc::new(tuned));
            reg.counter("tune.swaps").inc();
            swapped = true;
        }
    }
    if swapped {
        reg.reset_shards();
    }
}

/// Apply one `UpdateGraph` request: registry swap (epoch + 1) and an
/// in-place plan patch via [`PlanCache::refresh`]. The expensive work
/// happens here in the worker; submitters only ever contend on the
/// registry's pointer-swap lock.
///
/// Under persistence the batch is WAL-logged **before** the registry
/// applies it (DESIGN §11: logged == applied). A typed append failure —
/// disk full, I/O error — **sheds** the update: the client gets the
/// error, the registry stays at its epoch, and the WAL holds no record
/// of a batch that never applied. The converse can't happen either: a
/// logged batch passed submit-time bounds validation, the only way
/// [`GraphRegistry::update`] fails, so apply-after-log is infallible.
fn apply_update(
    u: UpdatePending,
    registry: &GraphRegistry,
    metrics: &ServeMetrics,
    cache: &PlanCache,
    params: PartitionParams,
    persist: Option<&ServePersist>,
) {
    let t0 = Instant::now();
    if let Some(p) = persist {
        let epoch = match registry.get(u.graph) {
            Ok(e) => e.epoch + 1,
            Err(e) => {
                metrics.errors.inc();
                let _ = u.reply.send(Err(e));
                return;
            }
        };
        match p.log_batch(u.graph, epoch, &u.updates) {
            Ok(bytes) => {
                if bytes > 0 {
                    metrics.wal_appends.inc();
                }
            }
            Err(e) => {
                metrics.shed_updates.inc();
                metrics.errors.inc();
                eprintln!("[store] shedding update for {:?} at epoch {epoch}: {e}", u.graph);
                let _ = u.reply.send(Err(anyhow::Error::new(e)));
                return;
            }
        }
    }
    match registry.update(u.graph, &u.updates) {
        Ok(gu) => {
            if let Some(p) = persist {
                // seal the applied epoch with the fingerprint recovery
                // must reproduce. Advisory: a failed seal leaves the
                // final batch "applied but unverified" on restart, it
                // must not shed an already-applied update
                match p.log_commit(u.graph, gu.new.epoch, gu.new.fingerprint) {
                    Ok(bytes) => {
                        if bytes > 0 {
                            metrics.wal_appends.inc();
                        }
                    }
                    Err(e) => {
                        metrics.wal_failures.inc();
                        eprintln!(
                            "[store] commit seal for {:?} epoch {} failed: {e}",
                            u.graph, gu.new.epoch
                        );
                    }
                }
                match p.maybe_snapshot(u.graph, &gu.new, || {
                    registry
                        .original_snapshot(u.graph)
                        .map_err(|e| StoreError::Config(format!("registry: {e}")))
                }) {
                    Ok(Some(_gen)) => metrics.snapshots_written.inc(),
                    Ok(None) => {}
                    Err(e) => {
                        // the WAL still holds the full tail (compaction
                        // only runs after a successful snapshot write),
                        // so recovery is unaffected — warn and count
                        metrics.wal_failures.inc();
                        eprintln!("[store] periodic snapshot for {:?} failed: {e}", u.graph);
                    }
                }
            }
            let old_key = GraphKey { fingerprint: gu.old.fingerprint, params };
            let plan_patched = match cache.peek(&old_key) {
                Some(old_plan) => {
                    match patch_identity_plan(
                        &old_plan,
                        &gu.new.relabeled,
                        &gu.changes,
                        Some(gu.new.fingerprint),
                    ) {
                        Ok((plan, _stats)) => {
                            cache.refresh(&old_key, Arc::new(plan));
                            true
                        }
                        // patching must never take the server down: drop
                        // the stale plan and let the next batch rebuild
                        Err(_) => {
                            cache.invalidate(&old_key);
                            false
                        }
                    }
                }
                None => false, // nothing resident; next batch builds fresh
            };
            let patch_secs = t0.elapsed().as_secs_f64();
            crate::obs::Registry::global()
                .record_span_ns("serve_round/apply_update", (patch_secs * 1e9) as u64);
            metrics.updates.inc();
            metrics.plan_swaps.inc();
            metrics.patch_latency.record(patch_secs);
            metrics.epoch.set_max(gu.new.epoch as i64);
            // the swapped-in entry serves a new topology: the footer's
            // kernel-variant line described the old plan, so scope it to
            // live plans — the next executed batch re-notes it fresh
            metrics.clear_kernel(&gu.new.name);
            metrics.total.record(u.enqueued.elapsed().as_secs_f64());
            let _ = u.reply.send(Ok(UpdateReport {
                epoch: gu.new.epoch,
                rows_changed: gu.changes.len(),
                staged_ops: gu.staged_ops,
                compacted: gu.compacted,
                plan_patched,
                patch_secs,
            }));
        }
        Err(e) => {
            metrics.errors.inc();
            let _ = u.reply.send(Err(e));
        }
    }
}

/// Reply to every member of a failed group (anyhow errors don't clone;
/// each member gets the formatted chain).
fn fail_group(group: Vec<ComputePending>, metrics: &ServeMetrics, e: &anyhow::Error) {
    for p in group {
        metrics.errors.inc();
        metrics.total.record(p.enqueued.elapsed().as_secs_f64());
        let _ = p.reply.send(Err(anyhow!("{e:#}")));
    }
}

fn run_spmm_group(
    group: Vec<ComputePending>,
    metrics: &ServeMetrics,
    batcher: &ColumnBatcher,
    pool: &ThreadPool,
    cache: &PlanCache,
    params: PartitionParams,
) {
    // all members share (graph, epoch): any member's captured entry is
    // the group's entry
    let entry = Arc::clone(&group[0].entry);
    let widths: Vec<usize> = group.iter().map(ComputePending::payload_width).collect();
    let plans = match batcher.plan(&widths) {
        Ok(p) => p,
        Err(e) => return fail_group(group, metrics, &e),
    };
    let reg = crate::obs::Registry::global();
    let plan = cache.plan_for_keyed(entry.fingerprint, &entry.relabeled, params);
    let n = entry.n;
    let mut members: Vec<Option<ComputePending>> = group.into_iter().map(Some).collect();
    for bp in &plans {
        // fuse: copy member columns into the padded fused matrix while
        // permuting rows into the relabeled domain (single pass)
        let mut fuse_span = reg.span("serve_round/fuse");
        let aw = bp.artifact_width;
        let mut fused = vec![0f32; n * aw];
        let mut col = 0usize;
        let mut widths = Vec::with_capacity(bp.members.len());
        let mut traces = Vec::with_capacity(bp.members.len());
        for &m in &bp.members {
            let p = members[m].as_ref().expect("each request fused once");
            let x = match &p.payload {
                Payload::Spmm { x } => x.as_f32().expect("validated at submit"),
                Payload::Gcn { .. } => unreachable!("spmm group"),
            };
            let c = p.payload_width();
            for (i, &orig) in entry.perm.iter().enumerate() {
                let o = orig as usize;
                fused[i * aw + col..i * aw + col + c].copy_from_slice(&x[o * c..(o + 1) * c]);
            }
            widths.push(c);
            traces.push(p.trace);
            col += c;
        }
        if fuse_span.is_recording() {
            fuse_span.annotate("traces", traces.clone());
        }
        drop(fuse_span);
        // zero-copy: the fused matrix is borrowed by the scoped shard
        // jobs directly — no Arc wrap, no input copy. The plan is built
        // FROM the relabeled matrix, so the executor's original-row-order
        // result is already in the relabeled domain.
        let exec_begin = crate::obs::epoch_now_ns();
        let t0 = Instant::now();
        let y = crate::pipeline::spmm_block_level_parallel(&plan, &fused, aw, pool);
        let spmm_secs = t0.elapsed().as_secs_f64();
        let exec_args = reg.enabled().then(|| {
            let mut a = crate::util::json::Json::obj();
            a.set("traces", traces.clone());
            a
        });
        reg.record_span_interval(
            "serve_round/execute",
            exec_begin,
            (spmm_secs * 1e9) as u64,
            exec_args,
        );
        metrics.spmm_stage.record(spmm_secs);
        let gflops = crate::spmm::spmm_gflops(plan.nnz(), aw, spmm_secs);
        // achieved bandwidth: the plan's analytic traffic-model bytes
        // at the fused width over the same wall time the GFLOP/s use
        let gbps = plan.traffic.bytes_total(aw) as f64 / spmm_secs.max(1e-12) / 1e9;
        metrics.note_kernel(&entry.name, plan.kernels.summary(crate::spmm::SimdLevel::best()));
        metrics.note_gbps(&entry.name, gbps);
        metrics.batches.inc();
        metrics.fused_requests.add(bp.members.len() as u64);
        // split: copy each member's columns back out, unpermuting rows
        // to the original node order
        let mut split_span = reg.span("serve_round/split");
        split_span.annotate("traces", traces);
        let mut col = 0usize;
        for (slot, &m) in bp.members.iter().enumerate() {
            let c = widths[slot];
            let mut out = vec![0f32; n * c];
            for (i, &orig) in entry.perm.iter().enumerate() {
                let o = orig as usize;
                out[o * c..(o + 1) * c].copy_from_slice(&y[i * aw + col..i * aw + col + c]);
            }
            col += c;
            let p = members[m].take().expect("each request split once");
            metrics.completed.inc();
            metrics.spmm_gflops.record(gflops);
            metrics.spmm_gbps.record(gbps);
            metrics.total.record(p.enqueued.elapsed().as_secs_f64());
            let _ = p.reply.send(Ok(Response { y: HostTensor::f32(&[n, c], out) }));
        }
        drop(split_span);
    }
    debug_assert!(members.iter().all(Option::is_none), "every member replied");
}

impl ComputePending {
    fn payload_width(&self) -> usize {
        match &self.payload {
            Payload::Spmm { x } | Payload::Gcn { x, .. } => x.shape()[1],
        }
    }
}

fn run_gcn_group(
    group: Vec<ComputePending>,
    metrics: &ServeMetrics,
    batcher: &ColumnBatcher,
    pool: &ThreadPool,
    cache: &PlanCache,
    params: PartitionParams,
) {
    let model = match &group[0].payload {
        Payload::Gcn { model, .. } => Arc::clone(model),
        Payload::Spmm { .. } => unreachable!("gcn group"),
    };
    let entry = Arc::clone(&group[0].entry);
    // pack members so that k · max_layer_width fits the ladder: the
    // batcher plans over each member's *widest* layer, which bounds
    // every per-layer fused width in the stack
    let budget: Vec<usize> = vec![model.max_width(); group.len()];
    let plans = match batcher.plan(&budget) {
        Ok(p) => p,
        Err(e) => return fail_group(group, metrics, &e),
    };
    let plan = cache.plan_for_keyed(entry.fingerprint, &entry.relabeled, params);
    let out_dim = model.config.out_dim;
    let n = entry.n;
    let mut members: Vec<Option<ComputePending>> = group.into_iter().map(Some).collect();
    for bp in &plans {
        // zero-copy ingress: borrow each member's feature slice as-is;
        // the forward's fused ingress gather permutes rows while
        // copying into the fused matrix, and its egress scatter returns
        // results already in the original node order — the standalone
        // permute_rows/unpermute_rows passes are gone
        let xs: Vec<&[f32]> = bp
            .members
            .iter()
            .map(|&m| {
                let p = members[m].as_ref().expect("each request forwarded once");
                match &p.payload {
                    Payload::Gcn { x, .. } => x.as_f32().expect("validated at submit"),
                    Payload::Spmm { .. } => unreachable!("gcn group"),
                }
            })
            .collect();
        let fw = GcnForward { plan: plan.as_ref(), pool };
        let exec_begin = crate::obs::epoch_now_ns();
        match fw.forward(&model, &xs, Some(&entry.perm)) {
            Ok((outs, timings)) => {
                let reg = crate::obs::Registry::global();
                let exec_args = reg.enabled().then(|| {
                    let traces: Vec<u64> = bp
                        .members
                        .iter()
                        .map(|&m| members[m].as_ref().map_or(0, |p| p.trace))
                        .collect();
                    let mut a = crate::util::json::Json::obj();
                    a.set("traces", traces);
                    a
                });
                reg.record_span_interval(
                    "serve_round/execute",
                    exec_begin,
                    ((timings.spmm_secs + timings.dense_secs) * 1e9) as u64,
                    exec_args,
                );
                metrics.spmm_stage.record(timings.spmm_secs);
                metrics.dense_stage.record(timings.dense_secs);
                let gflops = crate::spmm::gflops(
                    model.spmm_flops(plan.nnz(), bp.members.len()),
                    timings.spmm_secs,
                );
                // GCN traffic: one propagate per layer at fused width
                // k·d_in, summed via the plan's analytic traffic model
                let k = bp.members.len();
                let bytes: u64 = model
                    .dims()
                    .iter()
                    .map(|&(din, _)| plan.traffic.bytes_total(k * din))
                    .sum();
                let gbps = bytes as f64 / timings.spmm_secs.max(1e-12) / 1e9;
                metrics
                    .note_kernel(&entry.name, plan.kernels.summary(crate::spmm::SimdLevel::best()));
                metrics.note_gbps(&entry.name, gbps);
                metrics.batches.inc();
                metrics.fused_requests.add(bp.members.len() as u64);
                for (&m, out) in bp.members.iter().zip(outs) {
                    let p = members[m].take().expect("each request replied once");
                    metrics.completed.inc();
                    metrics.spmm_gflops.record(gflops);
                    metrics.spmm_gbps.record(gbps);
                    metrics.total.record(p.enqueued.elapsed().as_secs_f64());
                    let _ =
                        p.reply.send(Ok(Response { y: HostTensor::f32(&[n, out_dim], out) }));
                }
            }
            Err(e) => {
                let failed: Vec<ComputePending> =
                    bp.members.iter().filter_map(|&m| members[m].take()).collect();
                fail_group(failed, metrics, &e);
            }
        }
    }
    debug_assert!(members.iter().all(Option::is_none), "every member replied");
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModelConfig;
    use crate::serve::gcn::reference_forward;
    use crate::spmm::verify::assert_allclose;
    use crate::util::rng::Pcg;

    fn random_csr(seed: u64, n: usize) -> Csr {
        let mut rng = Pcg::seed_from(seed);
        let mut edges = vec![(0u32, 0u32, 1.0f32)];
        for r in 0..n {
            let d = if rng.f64() < 0.05 { rng.range(0, n) } else { rng.range(0, 7) };
            for _ in 0..d {
                edges.push((r as u32, rng.range(0, n) as u32, rng.f32() + 0.1));
            }
        }
        Csr::from_edges(n, n, &edges).unwrap()
    }

    fn features(rng: &mut Pcg, n: usize, c: usize) -> HostTensor {
        HostTensor::f32(&[n, c], (0..n * c).map(|_| rng.f32() - 0.5).collect())
    }

    /// The serve-level satellite property: batched-parallel serving
    /// matches the sequential exact executor for every response, across
    /// two resident graphs and mixed request kinds/widths.
    #[test]
    fn mixed_load_matches_exact_executor() {
        let server = Server::start(ServeConfig {
            threads: 2,
            ladder: vec![16, 32, 64],
            ..ServeConfig::default()
        })
        .unwrap();
        let g1 = random_csr(1, 40);
        let g2 = random_csr(2, 25);
        let h1 = server.register_graph("g1", &g1).unwrap();
        let h2 = server.register_graph("g2", &g2).unwrap();
        assert_eq!(server.resident_graphs(), 2);
        let m1 = Arc::new(GcnModel::random(ModelConfig::gcn(8, 6, 3, 2), 7));
        let m2 = Arc::new(GcnModel::random(ModelConfig::gcn(4, 4, 2, 3), 8));

        let mut rng = Pcg::seed_from(99);
        let mut expected: Vec<Vec<f32>> = Vec::new();
        let mut rxs = Vec::new();
        for i in 0..36 {
            let (csr, h, n) = if i % 3 == 0 { (&g2, h2, 25) } else { (&g1, h1, 40) };
            if i % 4 == 3 {
                let (model, hh, csr2, n2) =
                    if i % 3 == 0 { (&m2, h2, &g2, 25) } else { (&m1, h1, &g1, 40) };
                let x = features(&mut rng, n2, model.config.in_dim);
                expected.push(reference_forward(csr2, model, x.as_f32().unwrap()));
                rxs.push(server.submit_gcn(hh, Arc::clone(model), x).unwrap());
            } else {
                let w = *rng.choose(&[4usize, 8, 16, 24, 48]);
                let x = features(&mut rng, n, w);
                expected.push(csr.spmm_dense(x.as_f32().unwrap(), w));
                rxs.push(server.submit_spmm(h, x).unwrap());
            }
        }
        for (i, rx) in rxs.into_iter().enumerate() {
            let resp = rx.recv().expect("worker alive").expect("request served");
            assert_allclose(
                resp.y.as_f32().unwrap(),
                &expected[i],
                1e-3,
                1e-3,
                &format!("response {i}"),
            );
        }
        let m = server.metrics();
        assert_eq!(m.completed.get(), 36);
        assert_eq!(m.errors.get(), 0);
        assert!(m.batches.get() > 0);
        assert!(m.total.snapshot().count >= 36);
        // every served request gets a per-request GFLOP/s sample
        let g = m.spmm_gflops.snapshot();
        assert_eq!(g.count, 36);
        assert!(g.mean > 0.0 && g.mean.is_finite());
    }

    #[test]
    fn burst_fuses_requests_into_fewer_batches() {
        // pause the worker, stack a burst, resume: the whole backlog
        // drains as one round and must fuse into a single 128-wide batch
        let server = Server::start(ServeConfig {
            threads: 1,
            ladder: vec![128],
            ..ServeConfig::default()
        })
        .unwrap();
        let g = random_csr(3, 30);
        let h = server.register_graph("g", &g).unwrap();
        let mut rng = Pcg::seed_from(5);
        server.pause();
        let rxs: Vec<_> = (0..16)
            .map(|_| server.submit_spmm(h, features(&mut rng, 30, 8)).unwrap())
            .collect();
        server.resume();
        for rx in rxs {
            rx.recv().unwrap().unwrap();
        }
        let m = server.metrics();
        assert_eq!(m.completed.get(), 16);
        assert_eq!(m.batches.get(), 1, "16×8 columns fit one 128-wide batch exactly");
        assert!((m.fusion_factor() - 16.0).abs() < 1e-9);
    }

    #[test]
    fn typed_backpressure_carries_depth_and_capacity() {
        let server = Server::start_without_worker(ServeConfig {
            queue_capacity: 2,
            ladder: vec![32],
            ..ServeConfig::default()
        })
        .unwrap();
        let h = server.register_graph("g", &random_csr(50, 10)).unwrap();
        let mut rng = Pcg::seed_from(60);
        let _a = server.try_submit(Request { graph: h, payload: Payload::Spmm { x: features(&mut rng, 10, 8) } }).unwrap();
        let _b = server.try_submit(Request { graph: h, payload: Payload::Spmm { x: features(&mut rng, 10, 8) } }).unwrap();
        let err = server
            .try_submit(Request { graph: h, payload: Payload::Spmm { x: features(&mut rng, 10, 8) } })
            .unwrap_err();
        assert_eq!(err, SubmitError::Backpressure { depth: 2, capacity: 2 });
        assert!(err.is_retryable(), "back-pressure is the retryable failure");
        assert_eq!(err.to_string(), "queue full (2 pending, capacity 2)");
        // malformed requests are typed Invalid and never retryable
        let err = server
            .try_submit(Request {
                graph: GraphHandle(9),
                payload: Payload::Spmm { x: features(&mut rng, 10, 8) },
            })
            .unwrap_err();
        assert!(matches!(err, SubmitError::Invalid(_)), "{err:?}");
        assert!(!err.is_retryable());
    }

    #[test]
    fn deadline_sheds_at_pickup_then_rejects_at_admission() {
        let server = Server::start(ServeConfig {
            threads: 1,
            ladder: vec![32],
            ..ServeConfig::default()
        })
        .unwrap();
        let g = random_csr(51, 10);
        let h = server.register_graph("g", &g).unwrap();
        let mut rng = Pcg::seed_from(61);
        // queue a request with a 1ms budget, hold the worker past it
        server.pause();
        let rx = server
            .try_submit_with_deadline(
                Request { graph: h, payload: Payload::Spmm { x: features(&mut rng, 10, 8) } },
                Duration::from_millis(1),
            )
            .unwrap();
        std::thread::sleep(Duration::from_millis(30));
        server.resume();
        let err = rx.recv().unwrap().unwrap_err();
        assert!(err.to_string().contains("deadline unmet"), "{err}");
        assert_eq!(server.metrics().deadline_expired.get(), 1);
        assert_eq!(server.metrics().completed.get(), 0, "expired request never executed");
        // that ~30ms wait fed the admission EWMA: the same budget is now
        // rejected at the door, before queueing doomed work
        let err = server
            .try_submit_with_deadline(
                Request { graph: h, payload: Payload::Spmm { x: features(&mut rng, 10, 8) } },
                Duration::from_millis(1),
            )
            .unwrap_err();
        match err {
            SubmitError::Deadline { wait, .. } => {
                assert!(wait >= Duration::from_millis(1), "predicted wait {wait:?}")
            }
            other => panic!("expected Deadline, got {other:?}"),
        }
        assert_eq!(server.metrics().deadline_expired.get(), 2);
        // a generous budget still serves, correctly
        let x = features(&mut rng, 10, 8);
        let want = g.spmm_dense(x.as_f32().unwrap(), 8);
        let resp = server
            .try_submit_with_deadline(
                Request { graph: h, payload: Payload::Spmm { x } },
                Duration::from_secs(60),
            )
            .unwrap()
            .recv()
            .unwrap()
            .unwrap();
        assert_allclose(resp.y.as_f32().unwrap(), &want, 1e-4, 1e-4, "deadline-admitted spmm");
    }

    #[test]
    fn persisted_updates_survive_restart() {
        let dir = crate::store::test_dir("serve-restart");
        let g = random_csr(52, 30);
        let batch = vec![
            EdgeUpdate::Insert { row: 2, col: 17, val: 4.0 },
            EdgeUpdate::Insert { row: 9, col: 3, val: -1.5 },
            EdgeUpdate::Delete { row: 0, col: 0 },
        ];
        let cfg = || ServeConfig {
            threads: 1,
            ladder: vec![32],
            persist: Some(PersistConfig {
                fsync: crate::store::FsyncPolicy::Never,
                ..PersistConfig::new(&dir)
            }),
            ..ServeConfig::default()
        };
        {
            let mut server = Server::start(cfg()).unwrap();
            let h = server.register_graph("g", &g).unwrap();
            let rep = server.update_graph(h, batch.clone()).unwrap();
            assert_eq!(rep.epoch, 1);
            assert!(server.metrics().wal_appends.get() >= 2, "batch + commit seal logged");
            server.shutdown(); // drain → join → flush, in that order
        }
        // restart: recover instead of registering
        let server2 = Server::start(cfg()).unwrap();
        let recs = server2.recover_tenants().unwrap();
        assert_eq!(recs.len(), 1);
        let r = &recs[0];
        assert_eq!((r.name.as_str(), r.epoch, r.replayed_batches), ("g", 1, 1));
        assert!(r.fingerprint_verified, "sealed epoch must verify");
        // the recovered fingerprint equals the uncrashed oracle's
        let mut dg = crate::delta::DeltaGraph::new(g.clone());
        dg.apply(&batch).unwrap();
        let updated = dg.snapshot();
        assert_eq!(r.fingerprint, crate::store::relabeled_fingerprint(&updated));
        // plan pre-warmed under the recovered fingerprint
        let key = GraphKey { fingerprint: r.fingerprint, params: PartitionParams::default() };
        assert!(server2.plan_cache().peek(&key).is_some(), "recovery pre-warms the plan");
        // recovered tenant serves correctly and continues its chain
        let mut rng = Pcg::seed_from(62);
        let x = features(&mut rng, 30, 8);
        let want = updated.spmm_dense(x.as_f32().unwrap(), 8);
        let resp = server2.submit_spmm(r.handle, x).unwrap().recv().unwrap().unwrap();
        assert_allclose(resp.y.as_f32().unwrap(), &want, 1e-4, 1e-4, "post-recovery spmm");
        let rep = server2
            .update_graph(r.handle, vec![EdgeUpdate::Insert { row: 1, col: 1, val: 2.0 }])
            .unwrap();
        assert_eq!(rep.epoch, 2, "updates continue the recovered epoch chain");
        // re-registering over recovered history is refused, typed
        let err = server2.register_graph("g", &g).unwrap_err();
        assert!(err.to_string().contains("already exists"), "{err}");
        drop(server2);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn disk_full_sheds_update_with_typed_error_and_keeps_serving() {
        let dir = crate::store::test_dir("serve-diskfull");
        let g = random_csr(53, 20);
        let server = Server::start(ServeConfig {
            threads: 1,
            ladder: vec![32],
            persist: Some(PersistConfig {
                fsync: crate::store::FsyncPolicy::Never,
                // budget covers the first couple of batch + seal
                // records, then the device is "full"
                fault_spec: Some("disk-full=200".into()),
                ..PersistConfig::new(&dir)
            }),
            ..ServeConfig::default()
        })
        .unwrap();
        let h = server.register_graph("g", &g).unwrap();
        let mut epoch = 0u64;
        let mut shed = 0u64;
        for i in 0..6 {
            let batch = vec![EdgeUpdate::Insert { row: i, col: 19 - i, val: 1.0 }];
            match server.update_graph(h, batch) {
                Ok(rep) => epoch = rep.epoch,
                Err(e) => {
                    assert!(e.to_string().contains("disk full"), "typed DiskFull, got {e}");
                    shed += 1;
                }
            }
        }
        assert!(shed > 0, "the byte budget must eventually shed");
        assert_eq!(server.metrics().shed_updates.get(), shed);
        assert_eq!(
            server.graph_epoch(h).unwrap(),
            epoch,
            "shed updates never advance the tenant"
        );
        // serving itself is unaffected by a full disk
        let mut rng = Pcg::seed_from(63);
        let x = features(&mut rng, 20, 8);
        let resp = server.submit_spmm(h, x).unwrap().recv().unwrap();
        assert!(resp.is_ok(), "compute path keeps working under disk-full");
        drop(server);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn bounded_queue_rejects_when_full() {
        let server = Server::start_without_worker(ServeConfig {
            queue_capacity: 2,
            ladder: vec![32],
            ..ServeConfig::default()
        })
        .unwrap();
        let h = server.register_graph("g", &random_csr(4, 10)).unwrap();
        let mut rng = Pcg::seed_from(6);
        let _a = server.submit_spmm(h, features(&mut rng, 10, 8)).unwrap();
        let _b = server.submit_spmm(h, features(&mut rng, 10, 8)).unwrap();
        let err = server.submit_spmm(h, features(&mut rng, 10, 8)).unwrap_err();
        assert!(err.to_string().contains("queue full"), "{err}");
        assert_eq!(server.metrics().rejected.get(), 1);
        assert_eq!(server.metrics().queue_depth.get(), 2);
    }

    #[test]
    fn invalid_submissions_rejected() {
        let server = Server::start_without_worker(ServeConfig {
            ladder: vec![16, 32],
            ..ServeConfig::default()
        })
        .unwrap();
        let h = server.register_graph("g", &random_csr(5, 12)).unwrap();
        let mut rng = Pcg::seed_from(7);
        // width over the ladder
        assert!(server.submit_spmm(h, features(&mut rng, 12, 33)).is_err());
        // wrong node count
        assert!(server.submit_spmm(h, features(&mut rng, 11, 8)).is_err());
        // i32 payload
        let bad = HostTensor::i32(&[12, 4], vec![0; 48]);
        assert!(server.submit_spmm(h, bad).is_err());
        // unknown handle
        assert!(server.submit_spmm(GraphHandle(9), features(&mut rng, 12, 8)).is_err());
        // GCN whose hidden layer cannot fit the ladder
        let wide = Arc::new(GcnModel::random(ModelConfig::gcn(16, 64, 4, 2), 1));
        assert!(server.submit_gcn(h, wide, features(&mut rng, 12, 16)).is_err());
        // GCN with mismatched in_dim
        let m = Arc::new(GcnModel::random(ModelConfig::gcn(16, 8, 4, 2), 2));
        assert!(server.submit_gcn(h, m, features(&mut rng, 12, 8)).is_err());
        // model whose public fields disagree with its config: must be
        // rejected at submit, not panic the worker mid-batch
        let mut broken = GcnModel::random(ModelConfig::gcn(16, 8, 4, 2), 3);
        broken.weights.pop();
        assert!(server.submit_gcn(h, Arc::new(broken), features(&mut rng, 12, 16)).is_err());
        // out-of-bounds UpdateGraph
        assert!(server
            .submit_update(h, vec![EdgeUpdate::Insert { row: 50, col: 0, val: 1.0 }])
            .is_err());
        assert_eq!(server.metrics().rejected.get(), 7, "unknown handle precedes validation");
    }

    #[test]
    fn shutdown_serves_queued_requests() {
        let server = Server::start(ServeConfig {
            threads: 1,
            ladder: vec![64],
            ..ServeConfig::default()
        })
        .unwrap();
        let g = random_csr(8, 20);
        let h = server.register_graph("g", &g).unwrap();
        let mut rng = Pcg::seed_from(8);
        let rxs: Vec<_> = (0..6)
            .map(|_| server.submit_spmm(h, features(&mut rng, 20, 16)).unwrap())
            .collect();
        drop(server); // graceful: queued work is drained before the worker exits
        for rx in rxs {
            assert!(rx.recv().expect("reply delivered before shutdown").is_ok());
        }
    }

    #[test]
    fn update_graph_swaps_epoch_and_serves_new_topology() {
        let server = Server::start(ServeConfig {
            threads: 2,
            ladder: vec![32],
            ..ServeConfig::default()
        })
        .unwrap();
        let g = random_csr(9, 30);
        let h = server.register_graph("g", &g).unwrap();
        let mut rng = Pcg::seed_from(11);
        // warm the plan cache so the update patches instead of dropping
        server.submit_spmm(h, features(&mut rng, 30, 8)).unwrap().recv().unwrap().unwrap();
        let batch = vec![
            EdgeUpdate::Insert { row: 0, col: 29, val: 2.5 },
            EdgeUpdate::Insert { row: 7, col: 3, val: -1.0 },
            EdgeUpdate::Delete { row: 0, col: 0 },
        ];
        assert!(
            server.metrics().render().contains("spmm kernel [g]"),
            "warm batch noted its kernel variant"
        );
        let report = server.update_graph(h, batch.clone()).unwrap();
        assert_eq!(report.epoch, 1);
        assert!(report.plan_patched, "warm plan must be patched, not dropped");
        assert!(report.rows_changed >= 2);
        assert_eq!(server.graph_epoch(h).unwrap(), 1);
        // the epoch bump cleared the footer's kernel line: the variant
        // described the pre-update plan, which no longer serves anyone
        assert!(
            !server.metrics().render().contains("spmm kernel [g]"),
            "stale kernel-variant line must not survive the epoch bump"
        );
        // post-update responses match the dense reference on the NEW graph
        let mut dg = crate::delta::DeltaGraph::new(g);
        dg.apply(&batch).unwrap();
        let updated = dg.snapshot();
        let x = features(&mut rng, 30, 12);
        let want = updated.spmm_dense(x.as_f32().unwrap(), 12);
        let resp = server.submit_spmm(h, x).unwrap().recv().unwrap().unwrap();
        assert_allclose(resp.y.as_f32().unwrap(), &want, 1e-4, 1e-4, "post-update spmm");
        assert!(
            server.metrics().render().contains("spmm kernel [g]"),
            "the first post-update batch re-notes the fresh variant"
        );
        let m = server.metrics();
        assert_eq!(m.plan_swaps.get(), 1);
        assert_eq!(m.updates.get(), 1);
        assert_eq!(m.epoch.get(), 1);
        assert!(m.patch_latency.snapshot().count == 1);
    }

    #[test]
    fn in_flight_requests_finish_on_old_epoch() {
        // pause; queue compute A, then an update, then compute B; resume.
        // A captured epoch 0 and must see the old adjacency; B is
        // submitted after the update *reply*, so it sees epoch 1.
        let server = Server::start(ServeConfig {
            threads: 1,
            ladder: vec![32],
            ..ServeConfig::default()
        })
        .unwrap();
        let g = random_csr(10, 25);
        let h = server.register_graph("g", &g).unwrap();
        let mut rng = Pcg::seed_from(13);
        let xa = features(&mut rng, 25, 8);
        let want_old = g.spmm_dense(xa.as_f32().unwrap(), 8);
        server.pause();
        let rx_a = server.submit_spmm(h, xa).unwrap();
        let batch = vec![EdgeUpdate::Insert { row: 1, col: 24, val: 9.0 }];
        let rx_u = server.submit_update(h, batch.clone()).unwrap();
        server.resume();
        let a = rx_a.recv().unwrap().unwrap();
        assert_allclose(
            a.y.as_f32().unwrap(),
            &want_old,
            1e-4,
            1e-4,
            "in-flight request must execute on the epoch it captured",
        );
        let rep = rx_u.recv().unwrap().unwrap();
        assert_eq!(rep.epoch, 1);
        // after the update: new topology served
        let mut dg = crate::delta::DeltaGraph::new(g);
        dg.apply(&batch).unwrap();
        let updated = dg.snapshot();
        let xb = features(&mut rng, 25, 8);
        let want_new = updated.spmm_dense(xb.as_f32().unwrap(), 8);
        let b = server.submit_spmm(h, xb).unwrap().recv().unwrap().unwrap();
        assert_allclose(b.y.as_f32().unwrap(), &want_new, 1e-4, 1e-4, "post-update request");
    }

    #[test]
    fn gcn_correct_across_update_epochs() {
        let server = Server::start(ServeConfig {
            threads: 2,
            ladder: vec![16, 32],
            ..ServeConfig::default()
        })
        .unwrap();
        let g = random_csr(12, 30);
        let h = server.register_graph("g", &g).unwrap();
        let model = Arc::new(GcnModel::random(ModelConfig::gcn(8, 6, 3, 2), 5));
        let mut rng = Pcg::seed_from(21);
        let mut dg = crate::delta::DeltaGraph::new(g);
        for round in 0..3 {
            let batch: Vec<EdgeUpdate> = (0..4)
                .map(|_| EdgeUpdate::Insert {
                    row: rng.range(0, 30) as u32,
                    col: rng.range(0, 30) as u32,
                    val: rng.f32() + 0.1,
                })
                .collect();
            let rep = server.update_graph(h, batch.clone()).unwrap();
            assert_eq!(rep.epoch, round + 1);
            dg.apply(&batch).unwrap();
            let cur = dg.snapshot();
            let x = features(&mut rng, 30, 8);
            let want = reference_forward(&cur, &model, x.as_f32().unwrap());
            let resp = server.submit_gcn(h, Arc::clone(&model), x).unwrap().recv().unwrap().unwrap();
            assert_allclose(
                resp.y.as_f32().unwrap(),
                &want,
                1e-3,
                1e-3,
                &format!("gcn after epoch {}", round + 1),
            );
        }
        assert_eq!(server.metrics().plan_swaps.get(), 3);
    }

    /// The closed-loop satellite at serve scope: with tuning enabled on
    /// every round, responses stay correct across any plan swap the
    /// tuner performs (tuned plans are bit-identical by construction),
    /// and the tuner's analysis shows up on the shared timeline.
    #[test]
    fn tuning_rounds_keep_serving_correctly() {
        let reg = crate::obs::Registry::global();
        reg.set_enabled(true);
        let server = Server::start(ServeConfig {
            threads: 2,
            ladder: vec![32],
            tune_every: 1,
            ..ServeConfig::default()
        })
        .unwrap();
        let g = random_csr(40, 60);
        let h = server.register_graph("g", &g).unwrap();
        let mut rng = Pcg::seed_from(77);
        for i in 0..8 {
            let x = features(&mut rng, 60, 8);
            let want = g.spmm_dense(x.as_f32().unwrap(), 8);
            let resp = server.submit_spmm(h, x).unwrap().recv().unwrap().unwrap();
            assert_allclose(
                resp.y.as_f32().unwrap(),
                &want,
                1e-3,
                1e-3,
                &format!("tuned round {i}"),
            );
        }
        drop(server); // join the worker: every round's tune pass has run
        let evs = reg.trace_events(usize::MAX);
        assert!(
            evs.iter().any(|e| e.name == "plan_tune"),
            "the tuner must have analyzed at least once after warmup"
        );
    }
}
