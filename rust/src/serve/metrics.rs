//! Serve-subsystem metrics: queue depth, batch occupancy, and
//! per-stage latency recorders — all built on [`crate::metrics`]
//! primitives (fixed log-bucket histograms with exact count/mean/max
//! and quantiles within a documented ≤ 5% bound — actual bound
//! `2^(1/32)−1 ≈ 2.2%`, one-sided — so a server that runs forever
//! holds constant memory with no sampling).

use crate::metrics::{Counter, Gauge, LatencyRecorder, LatencySnapshot};
use crate::util::json::Json;
use std::collections::BTreeMap;
use std::sync::Mutex;

/// Shared between submitters (front edge) and the worker loop.
#[derive(Debug, Default)]
pub struct ServeMetrics {
    /// Accepted into the queue.
    pub submitted: Counter,
    /// Bounced off a full queue.
    pub rejected: Counter,
    /// Replied successfully.
    pub completed: Counter,
    /// Replied with an error.
    pub errors: Counter,
    /// Fused batches executed.
    pub batches: Counter,
    /// Requests carried by those batches (occupancy numerator).
    pub fused_requests: Counter,
    /// Pending requests right now.
    pub queue_depth: Gauge,
    /// submit → worker pickup.
    pub queue_wait: LatencyRecorder,
    /// sparse traversal stage (per fused batch).
    pub spmm_stage: LatencyRecorder,
    /// Achieved SpMM throughput, GFLOP/s, recorded **per request** (a
    /// fused batch's rate is credited to every member riding it —
    /// 2·nnz·width flops over the batch's spmm wall time).
    pub spmm_gflops: LatencyRecorder,
    /// Achieved SpMM memory bandwidth, GB/s, recorded **per request**
    /// like [`Self::spmm_gflops`] — the plan's analytic
    /// [`TrafficModel`](crate::pipeline::TrafficModel) bytes at the
    /// batch's width over the batch's spmm wall time.
    pub spmm_gbps: LatencyRecorder,
    /// dense affine stage (per fused batch; GCN requests only).
    pub dense_stage: LatencyRecorder,
    /// submit → reply.
    pub total: LatencyRecorder,
    /// `UpdateGraph` requests applied.
    pub updates: Counter,
    /// Epoch swaps published to tenants (one per applied update).
    pub plan_swaps: Counter,
    /// Registry swap + plan patch time per update.
    pub patch_latency: LatencyRecorder,
    /// Highest epoch any tenant has reached.
    pub epoch: Gauge,
    /// Updates shed because their WAL append failed (disk full, I/O) —
    /// the client got a typed error and the tenant did not advance.
    pub shed_updates: Counter,
    /// Compute requests rejected at admission or dropped at pickup
    /// because their deadline could not be met.
    pub deadline_expired: Counter,
    /// WAL records (batch + commit) appended.
    pub wal_appends: Counter,
    /// Advisory durability failures (commit seal, periodic snapshot) —
    /// serving continued, recovery guarantees degraded as documented.
    pub wal_failures: Counter,
    /// Periodic snapshot generations written.
    pub snapshots_written: Counter,
    /// Kernel variant that last served each tenant (graph name →
    /// variant tag, e.g. `"avx2+adaptive(dense 3 / sparse 40 blocks)"`)
    /// — recorded by the worker per executed batch, rendered in the
    /// footer. BTreeMap for deterministic footer order.
    tenant_kernels: Mutex<BTreeMap<String, String>>,
    /// Achieved GB/s of each tenant's last executed batch (graph name
    /// → GB/s) — same lifecycle as `tenant_kernels`: overwritten per
    /// batch, cleared by [`Self::clear_kernel`] on eviction or epoch
    /// bump so the footer never reports a retired plan's bandwidth.
    tenant_gbps: Mutex<BTreeMap<String, f64>>,
}

impl ServeMetrics {
    pub fn new() -> ServeMetrics {
        ServeMetrics::default()
    }

    /// Record which kernel variant served `tenant`'s last executed
    /// batch (overwrites: the footer shows the current variant, which
    /// can change when a plan patch moves blocks across the
    /// dense/sparse crossover).
    pub fn note_kernel(&self, tenant: &str, variant: String) {
        let mut map = self.tenant_kernels.lock().unwrap();
        match map.get_mut(tenant) {
            Some(v) => *v = variant,
            None => {
                map.insert(tenant.to_string(), variant);
            }
        }
    }

    /// Record the achieved bandwidth of `tenant`'s last executed batch
    /// (overwrites, like [`Self::note_kernel`]).
    pub fn note_gbps(&self, tenant: &str, gbps: f64) {
        self.tenant_gbps.lock().unwrap().insert(tenant.to_string(), gbps);
    }

    /// Forget `tenant`'s kernel-variant footer line *and* its achieved
    /// GB/s. Called when a tenant's plan is evicted or replaced by an
    /// epoch bump: the noted variant and bandwidth described the *old*
    /// plan (the new graph has different traffic), and a footer that
    /// keeps rendering them would report a kernel mix and byte rate no
    /// live plan uses. Both lines reappear (fresh) on the tenant's next
    /// executed batch.
    pub fn clear_kernel(&self, tenant: &str) {
        self.tenant_kernels.lock().unwrap().remove(tenant);
        self.tenant_gbps.lock().unwrap().remove(tenant);
    }

    /// Mean requests fused per executed batch (> 1 means the column
    /// batcher is amortizing traversals across requests).
    pub fn fusion_factor(&self) -> f64 {
        let batches = self.batches.get();
        if batches == 0 {
            return 0.0;
        }
        self.fused_requests.get() as f64 / batches as f64
    }

    /// Multi-line human report (the `serve-native` subcommand's footer).
    pub fn render(&self) -> String {
        let mut s = String::new();
        s.push_str(&format!(
            "requests: submitted={} rejected={} completed={} errors={} queue_depth={}\n",
            self.submitted.get(),
            self.rejected.get(),
            self.completed.get(),
            self.errors.get(),
            self.queue_depth.get(),
        ));
        s.push_str(&format!(
            "batches: {} executed, fusion factor {:.2} requests/batch\n",
            self.batches.get(),
            self.fusion_factor(),
        ));
        s.push_str(&format!(
            "updates: {} applied, {} plan swaps, epoch {}\n",
            self.updates.get(),
            self.plan_swaps.get(),
            self.epoch.get(),
        ));
        s.push_str(&format!(
            "robustness: shed_updates={} deadline_expired={} wal_appends={} wal_failures={} snapshots={}\n",
            self.shed_updates.get(),
            self.deadline_expired.get(),
            self.wal_appends.get(),
            self.wal_failures.get(),
            self.snapshots_written.get(),
        ));
        s.push_str(&format!("{}\n", self.queue_wait.snapshot().render("queue wait")));
        s.push_str(&format!("{}\n", self.spmm_stage.snapshot().render("spmm stage")));
        let g = self.spmm_gflops.snapshot();
        s.push_str(&format!(
            "spmm throughput: mean {:.3} GFLOP/s, max {:.3} GFLOP/s over {} requests\n",
            g.mean, g.max, g.count
        ));
        let b = self.spmm_gbps.snapshot();
        if b.count > 0 {
            s.push_str(&format!(
                "spmm bandwidth: mean {:.3} GB/s, max {:.3} GB/s over {} requests\n",
                b.mean, b.max, b.count
            ));
        }
        let gbps = self.tenant_gbps.lock().unwrap();
        for (tenant, variant) in self.tenant_kernels.lock().unwrap().iter() {
            match gbps.get(tenant) {
                Some(r) => s.push_str(&format!(
                    "spmm kernel [{tenant}]: {variant} @ {r:.2} GB/s\n"
                )),
                None => s.push_str(&format!("spmm kernel [{tenant}]: {variant}\n")),
            }
        }
        drop(gbps);
        s.push_str(&format!("{}\n", self.dense_stage.snapshot().render("dense stage")));
        s.push_str(&format!("{}\n", self.patch_latency.snapshot().render("plan patch")));
        s.push_str(&format!("{}\n", self.total.snapshot().render("total")));
        s
    }

    /// Everything above as the snapshot schema's `serve` section —
    /// merged into the registry document `serve-native --metrics-out`
    /// writes (`{counters, gauges, fusion_factor, latencies, kernels}`;
    /// `latencies.*` use the shared histogram summary shape the CI
    /// validator checks).
    pub fn snapshot_json(&self) -> Json {
        fn lat(s: &LatencySnapshot) -> Json {
            let mut o = Json::obj();
            o.set("count", s.count);
            o.set("mean", s.mean);
            o.set("p50", s.p50);
            o.set("p95", s.p95);
            o.set("p99", s.p99);
            o.set("max", s.max);
            o
        }
        let mut doc = Json::obj();
        let mut counters = Json::obj();
        counters.set("submitted", self.submitted.get());
        counters.set("rejected", self.rejected.get());
        counters.set("completed", self.completed.get());
        counters.set("errors", self.errors.get());
        counters.set("batches", self.batches.get());
        counters.set("fused_requests", self.fused_requests.get());
        counters.set("updates", self.updates.get());
        counters.set("plan_swaps", self.plan_swaps.get());
        counters.set("shed_updates", self.shed_updates.get());
        counters.set("deadline_expired", self.deadline_expired.get());
        counters.set("wal_appends", self.wal_appends.get());
        counters.set("wal_failures", self.wal_failures.get());
        counters.set("snapshots_written", self.snapshots_written.get());
        doc.set("counters", counters);
        let mut gauges = Json::obj();
        gauges.set("queue_depth", self.queue_depth.get());
        gauges.set("epoch", self.epoch.get());
        doc.set("gauges", gauges);
        doc.set("fusion_factor", self.fusion_factor());
        let mut latencies = Json::obj();
        latencies.set("queue_wait", lat(&self.queue_wait.snapshot()));
        latencies.set("spmm_stage", lat(&self.spmm_stage.snapshot()));
        latencies.set("spmm_gflops", lat(&self.spmm_gflops.snapshot()));
        latencies.set("spmm_gbps", lat(&self.spmm_gbps.snapshot()));
        latencies.set("dense_stage", lat(&self.dense_stage.snapshot()));
        latencies.set("patch_latency", lat(&self.patch_latency.snapshot()));
        latencies.set("total", lat(&self.total.snapshot()));
        doc.set("latencies", latencies);
        let mut kernels = Json::obj();
        for (tenant, variant) in self.tenant_kernels.lock().unwrap().iter() {
            kernels.set(tenant, variant.as_str());
        }
        doc.set("kernels", kernels);
        let mut gbps = Json::obj();
        for (tenant, rate) in self.tenant_gbps.lock().unwrap().iter() {
            gbps.set(tenant, *rate);
        }
        doc.set("tenant_gbps", gbps);
        doc
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fusion_factor_and_render() {
        let m = ServeMetrics::new();
        assert_eq!(m.fusion_factor(), 0.0, "no batches yet");
        m.batches.add(2);
        m.fused_requests.add(7);
        assert!((m.fusion_factor() - 3.5).abs() < 1e-12);
        m.submitted.add(7);
        m.completed.add(7);
        m.queue_depth.set(0);
        m.total.record(0.001);
        m.spmm_gflops.record(1.25);
        m.spmm_gflops.record(2.75);
        let r = m.render();
        assert!(r.contains("fusion factor 3.50"));
        assert!(r.contains("submitted=7"));
        assert!(r.contains("spmm throughput: mean 2.000 GFLOP/s"), "{r}");
        assert!(r.contains("over 2 requests"), "{r}");
    }

    #[test]
    fn kernel_variants_render_per_tenant() {
        let m = ServeMetrics::new();
        assert!(!m.render().contains("spmm kernel"), "no tenants yet");
        m.note_kernel("cora", "scalar+adaptive(dense 1 / sparse 2 blocks)".into());
        m.note_kernel("collab", "portable-simd+adaptive(dense 5 / sparse 0 blocks)".into());
        // re-noting overwrites (plan patch changed the schedule)
        m.note_kernel("cora", "scalar+adaptive(dense 2 / sparse 1 blocks)".into());
        let r = m.render();
        assert!(r.contains("spmm kernel [cora]: scalar+adaptive(dense 2 / sparse 1 blocks)"), "{r}");
        assert!(r.contains("spmm kernel [collab]: portable-simd+adaptive"), "{r}");
        assert!(!r.contains("dense 1 / sparse 2"), "stale variant must be replaced");
    }

    #[test]
    fn clear_kernel_scopes_footer_to_live_plans() {
        let m = ServeMetrics::new();
        m.note_kernel("g", "scalar+adaptive(dense 1 / sparse 2 blocks)".into());
        m.note_kernel("h", "scalar+adaptive(dense 4 / sparse 0 blocks)".into());
        assert!(m.render().contains("spmm kernel [g]"));
        // g's plan was evicted / epoch-bumped: its stale variant line
        // must disappear, other tenants' lines must survive
        m.clear_kernel("g");
        let r = m.render();
        assert!(!r.contains("spmm kernel [g]"), "{r}");
        assert!(r.contains("spmm kernel [h]"), "{r}");
        m.clear_kernel("never-noted"); // no-op, must not panic
        // the next executed batch brings the line back, fresh
        m.note_kernel("g", "scalar+adaptive(dense 0 / sparse 3 blocks)".into());
        assert!(m.render().contains("spmm kernel [g]: scalar+adaptive(dense 0 / sparse 3 blocks)"));
    }

    #[test]
    fn epoch_bump_clears_tenant_gbps_with_kernel() {
        // PR 7 fixed stale kernel-variant lines surviving epoch bumps;
        // the GB/s footer state must ride the same lifecycle, or the
        // footer keeps quoting the *old* graph's bandwidth after an
        // UpdateGraph swap.
        let m = ServeMetrics::new();
        m.note_kernel("g", "scalar+adaptive(dense 1 / sparse 2 blocks)".into());
        m.note_gbps("g", 12.5);
        m.note_kernel("h", "scalar+adaptive(dense 4 / sparse 0 blocks)".into());
        m.note_gbps("h", 7.25);
        let r = m.render();
        assert!(r.contains("spmm kernel [g]") && r.contains("@ 12.50 GB/s"), "{r}");
        assert!(r.contains("@ 7.25 GB/s"), "{r}");
        // epoch bump on g: both its footer lines go; h's survive
        m.clear_kernel("g");
        let r = m.render();
        assert!(!r.contains("spmm kernel [g]"), "{r}");
        assert!(!r.contains("12.50"), "stale bandwidth must be cleared: {r}");
        assert!(r.contains("@ 7.25 GB/s"), "{r}");
        let doc = m.snapshot_json();
        assert!(doc.get("tenant_gbps").unwrap().get("g").is_none());
        assert!(
            (doc.get("tenant_gbps").unwrap().req_f64("h").unwrap() - 7.25).abs() < 1e-12
        );
        // next executed batch re-notes, fresh
        m.note_gbps("g", 3.0);
        m.note_kernel("g", "scalar+adaptive(dense 0 / sparse 3 blocks)".into());
        assert!(m.render().contains("@ 3.00 GB/s"));
    }

    #[test]
    fn snapshot_json_has_schema_shape() {
        let m = ServeMetrics::new();
        m.submitted.add(5);
        m.completed.add(4);
        m.batches.add(2);
        m.fused_requests.add(4);
        m.queue_wait.record(0.001);
        m.total.record(0.004);
        m.total.record(0.002);
        m.note_kernel("g", "scalar+adaptive(dense 1 / sparse 0 blocks)".into());
        let doc = m.snapshot_json();
        assert_eq!(doc.get("counters").unwrap().req_f64("submitted").unwrap(), 5.0);
        assert!((doc.req_f64("fusion_factor").unwrap() - 2.0).abs() < 1e-12);
        let total = doc.get("latencies").unwrap().get("total").unwrap();
        assert_eq!(total.req_usize("count").unwrap(), 2);
        assert!(total.req_f64("p99").unwrap() >= total.req_f64("p50").unwrap());
        assert_eq!(
            doc.get("kernels").unwrap().req_str("g").unwrap(),
            "scalar+adaptive(dense 1 / sparse 0 blocks)"
        );
        // round-trips through text like the --metrics-out file does
        let back = Json::parse(&doc.to_pretty()).unwrap();
        assert_eq!(back, doc);
    }

    #[test]
    fn update_path_metrics_render() {
        let m = ServeMetrics::new();
        m.updates.add(3);
        m.plan_swaps.add(3);
        m.epoch.set(3);
        m.patch_latency.record(0.002);
        let r = m.render();
        assert!(r.contains("updates: 3 applied, 3 plan swaps, epoch 3"), "{r}");
        assert!(r.contains("plan patch"), "{r}");
        assert_eq!(m.patch_latency.snapshot().count, 1);
    }
}
