//! Serve-subsystem metrics: queue depth, batch occupancy, and
//! per-stage latency recorders — all built on [`crate::metrics`]
//! primitives (bounded reservoirs, so a server that runs forever holds
//! constant memory).

use crate::metrics::{Counter, Gauge, LatencyRecorder};
use std::collections::BTreeMap;
use std::sync::Mutex;

/// Shared between submitters (front edge) and the worker loop.
#[derive(Debug, Default)]
pub struct ServeMetrics {
    /// Accepted into the queue.
    pub submitted: Counter,
    /// Bounced off a full queue.
    pub rejected: Counter,
    /// Replied successfully.
    pub completed: Counter,
    /// Replied with an error.
    pub errors: Counter,
    /// Fused batches executed.
    pub batches: Counter,
    /// Requests carried by those batches (occupancy numerator).
    pub fused_requests: Counter,
    /// Pending requests right now.
    pub queue_depth: Gauge,
    /// submit → worker pickup.
    pub queue_wait: LatencyRecorder,
    /// sparse traversal stage (per fused batch).
    pub spmm_stage: LatencyRecorder,
    /// Achieved SpMM throughput, GFLOP/s, recorded **per request** (a
    /// fused batch's rate is credited to every member riding it —
    /// 2·nnz·width flops over the batch's spmm wall time).
    pub spmm_gflops: LatencyRecorder,
    /// dense affine stage (per fused batch; GCN requests only).
    pub dense_stage: LatencyRecorder,
    /// submit → reply.
    pub total: LatencyRecorder,
    /// `UpdateGraph` requests applied.
    pub updates: Counter,
    /// Epoch swaps published to tenants (one per applied update).
    pub plan_swaps: Counter,
    /// Registry swap + plan patch time per update.
    pub patch_latency: LatencyRecorder,
    /// Highest epoch any tenant has reached.
    pub epoch: Gauge,
    /// Kernel variant that last served each tenant (graph name →
    /// variant tag, e.g. `"avx2+adaptive(dense 3 / sparse 40 blocks)"`)
    /// — recorded by the worker per executed batch, rendered in the
    /// footer. BTreeMap for deterministic footer order.
    tenant_kernels: Mutex<BTreeMap<String, String>>,
}

impl ServeMetrics {
    pub fn new() -> ServeMetrics {
        ServeMetrics::default()
    }

    /// Record which kernel variant served `tenant`'s last executed
    /// batch (overwrites: the footer shows the current variant, which
    /// can change when a plan patch moves blocks across the
    /// dense/sparse crossover).
    pub fn note_kernel(&self, tenant: &str, variant: String) {
        let mut map = self.tenant_kernels.lock().unwrap();
        match map.get_mut(tenant) {
            Some(v) => *v = variant,
            None => {
                map.insert(tenant.to_string(), variant);
            }
        }
    }

    /// Mean requests fused per executed batch (> 1 means the column
    /// batcher is amortizing traversals across requests).
    pub fn fusion_factor(&self) -> f64 {
        let batches = self.batches.get();
        if batches == 0 {
            return 0.0;
        }
        self.fused_requests.get() as f64 / batches as f64
    }

    /// Multi-line human report (the `serve-native` subcommand's footer).
    pub fn render(&self) -> String {
        let mut s = String::new();
        s.push_str(&format!(
            "requests: submitted={} rejected={} completed={} errors={} queue_depth={}\n",
            self.submitted.get(),
            self.rejected.get(),
            self.completed.get(),
            self.errors.get(),
            self.queue_depth.get(),
        ));
        s.push_str(&format!(
            "batches: {} executed, fusion factor {:.2} requests/batch\n",
            self.batches.get(),
            self.fusion_factor(),
        ));
        s.push_str(&format!(
            "updates: {} applied, {} plan swaps, epoch {}\n",
            self.updates.get(),
            self.plan_swaps.get(),
            self.epoch.get(),
        ));
        s.push_str(&format!("{}\n", self.queue_wait.snapshot().render("queue wait")));
        s.push_str(&format!("{}\n", self.spmm_stage.snapshot().render("spmm stage")));
        let g = self.spmm_gflops.snapshot();
        s.push_str(&format!(
            "spmm throughput: mean {:.3} GFLOP/s, max {:.3} GFLOP/s over {} requests\n",
            g.mean, g.max, g.count
        ));
        for (tenant, variant) in self.tenant_kernels.lock().unwrap().iter() {
            s.push_str(&format!("spmm kernel [{tenant}]: {variant}\n"));
        }
        s.push_str(&format!("{}\n", self.dense_stage.snapshot().render("dense stage")));
        s.push_str(&format!("{}\n", self.patch_latency.snapshot().render("plan patch")));
        s.push_str(&format!("{}\n", self.total.snapshot().render("total")));
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fusion_factor_and_render() {
        let m = ServeMetrics::new();
        assert_eq!(m.fusion_factor(), 0.0, "no batches yet");
        m.batches.add(2);
        m.fused_requests.add(7);
        assert!((m.fusion_factor() - 3.5).abs() < 1e-12);
        m.submitted.add(7);
        m.completed.add(7);
        m.queue_depth.set(0);
        m.total.record(0.001);
        m.spmm_gflops.record(1.25);
        m.spmm_gflops.record(2.75);
        let r = m.render();
        assert!(r.contains("fusion factor 3.50"));
        assert!(r.contains("submitted=7"));
        assert!(r.contains("spmm throughput: mean 2.000 GFLOP/s"), "{r}");
        assert!(r.contains("over 2 requests"), "{r}");
    }

    #[test]
    fn kernel_variants_render_per_tenant() {
        let m = ServeMetrics::new();
        assert!(!m.render().contains("spmm kernel"), "no tenants yet");
        m.note_kernel("cora", "scalar+adaptive(dense 1 / sparse 2 blocks)".into());
        m.note_kernel("collab", "portable-simd+adaptive(dense 5 / sparse 0 blocks)".into());
        // re-noting overwrites (plan patch changed the schedule)
        m.note_kernel("cora", "scalar+adaptive(dense 2 / sparse 1 blocks)".into());
        let r = m.render();
        assert!(r.contains("spmm kernel [cora]: scalar+adaptive(dense 2 / sparse 1 blocks)"), "{r}");
        assert!(r.contains("spmm kernel [collab]: portable-simd+adaptive"), "{r}");
        assert!(!r.contains("dense 1 / sparse 2"), "stale variant must be replaced");
    }

    #[test]
    fn update_path_metrics_render() {
        let m = ServeMetrics::new();
        m.updates.add(3);
        m.plan_swaps.add(3);
        m.epoch.set(3);
        m.patch_latency.record(0.002);
        let r = m.render();
        assert!(r.contains("updates: 3 applied, 3 plan swaps, epoch 3"), "{r}");
        assert!(r.contains("plan patch"), "{r}");
        assert_eq!(m.patch_latency.snapshot().count, 1);
    }
}
