//! Model configuration mirrored with `python/compile/model.py` and the
//! AOT manifest.

use crate::util::json::Json;
use anyhow::Result;

/// GCN-family architecture description (parsed from manifest.json).
#[derive(Clone, Debug, PartialEq)]
pub struct ModelConfig {
    pub arch: String,
    pub in_dim: usize,
    pub hidden_dim: usize,
    pub out_dim: usize,
    pub n_layers: usize,
    pub lr: f64,
    pub n_params: usize,
}

impl ModelConfig {
    pub fn from_json(j: &Json) -> Result<ModelConfig> {
        Ok(ModelConfig {
            arch: j.req_str("arch")?.to_string(),
            in_dim: j.req_usize("in_dim")?,
            hidden_dim: j.req_usize("hidden_dim")?,
            out_dim: j.req_usize("out_dim")?,
            n_layers: j.req_usize("n_layers")?,
            lr: j.req_f64("lr")?,
            n_params: j.req_usize("n_params")?,
        })
    }

    /// Parameters per layer, mirroring model.params_per_layer.
    pub fn params_per_layer(&self) -> usize {
        match self.arch.as_str() {
            "gcn" => 2,
            "sage" => 3,
            "gin" => 4,
            other => panic!("unknown arch {other}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_from_manifest_json() {
        let j = Json::parse(
            r#"{"arch":"gcn","in_dim":64,"hidden_dim":64,"out_dim":8,"n_layers":2,"lr":0.05,"n_params":4}"#,
        )
        .unwrap();
        let m = ModelConfig::from_json(&j).unwrap();
        assert_eq!(m.arch, "gcn");
        assert_eq!(m.params_per_layer(), 2);
        assert_eq!(m.n_params, 4);
    }

    #[test]
    fn rejects_missing_fields() {
        let j = Json::parse(r#"{"arch":"gcn"}"#).unwrap();
        assert!(ModelConfig::from_json(&j).is_err());
    }
}
