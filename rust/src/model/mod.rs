//! Model configuration mirrored with `python/compile/model.py` and the
//! AOT manifest.

use crate::util::json::Json;
use anyhow::Result;

/// GCN-family architecture description (parsed from manifest.json).
#[derive(Clone, Debug, PartialEq)]
pub struct ModelConfig {
    pub arch: String,
    pub in_dim: usize,
    pub hidden_dim: usize,
    pub out_dim: usize,
    pub n_layers: usize,
    pub lr: f64,
    pub n_params: usize,
}

impl ModelConfig {
    /// A GCN stack description built directly from dimensions (the
    /// native serve path has no manifest.json to parse). `n_layers ≥ 1`;
    /// a 1-layer model maps `in_dim → out_dim` directly.
    pub fn gcn(in_dim: usize, hidden_dim: usize, out_dim: usize, n_layers: usize) -> ModelConfig {
        assert!(n_layers >= 1, "GCN needs at least one layer");
        assert!(in_dim > 0 && hidden_dim > 0 && out_dim > 0, "dims must be positive");
        ModelConfig {
            arch: "gcn".to_string(),
            in_dim,
            hidden_dim,
            out_dim,
            n_layers,
            lr: 0.0,
            n_params: 2 * n_layers,
        }
    }

    /// Set the learning rate (builder style). [`ModelConfig::gcn`]
    /// deliberately leaves `lr` at 0.0 — inference never reads it — so
    /// every training consumer must pass through here (and the training
    /// entry points validate `lr > 0` before running a step).
    pub fn with_lr(mut self, lr: f64) -> ModelConfig {
        assert!(lr.is_finite() && lr > 0.0, "learning rate must be positive, got {lr}");
        self.lr = lr;
        self
    }

    /// `(in, out)` dimensions of every layer in the stack.
    pub fn layer_dims(&self) -> Vec<(usize, usize)> {
        (0..self.n_layers)
            .map(|l| {
                let din = if l == 0 { self.in_dim } else { self.hidden_dim };
                let dout = if l + 1 == self.n_layers { self.out_dim } else { self.hidden_dim };
                (din, dout)
            })
            .collect()
    }

    pub fn from_json(j: &Json) -> Result<ModelConfig> {
        Ok(ModelConfig {
            arch: j.req_str("arch")?.to_string(),
            in_dim: j.req_usize("in_dim")?,
            hidden_dim: j.req_usize("hidden_dim")?,
            out_dim: j.req_usize("out_dim")?,
            n_layers: j.req_usize("n_layers")?,
            lr: j.req_f64("lr")?,
            n_params: j.req_usize("n_params")?,
        })
    }

    /// Parameters per layer, mirroring model.params_per_layer.
    pub fn params_per_layer(&self) -> usize {
        match self.arch.as_str() {
            "gcn" => 2,
            "sage" => 3,
            "gin" => 4,
            other => panic!("unknown arch {other}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_from_manifest_json() {
        let j = Json::parse(
            r#"{"arch":"gcn","in_dim":64,"hidden_dim":64,"out_dim":8,"n_layers":2,"lr":0.05,"n_params":4}"#,
        )
        .unwrap();
        let m = ModelConfig::from_json(&j).unwrap();
        assert_eq!(m.arch, "gcn");
        assert_eq!(m.params_per_layer(), 2);
        assert_eq!(m.n_params, 4);
    }

    #[test]
    fn rejects_missing_fields() {
        let j = Json::parse(r#"{"arch":"gcn"}"#).unwrap();
        assert!(ModelConfig::from_json(&j).is_err());
    }

    #[test]
    fn with_lr_sets_rate() {
        let m = ModelConfig::gcn(8, 4, 2, 2);
        assert_eq!(m.lr, 0.0, "inference constructor leaves lr unset");
        let m = m.with_lr(0.05);
        assert_eq!(m.lr, 0.05);
    }

    #[test]
    #[should_panic(expected = "learning rate must be positive")]
    fn with_lr_rejects_zero() {
        let _ = ModelConfig::gcn(8, 4, 2, 2).with_lr(0.0);
    }

    #[test]
    fn gcn_layer_dims_chain() {
        let m = ModelConfig::gcn(64, 32, 8, 3);
        assert_eq!(m.layer_dims(), vec![(64, 32), (32, 32), (32, 8)]);
        assert_eq!(m.params_per_layer(), 2);
        let one = ModelConfig::gcn(16, 99, 4, 1);
        assert_eq!(one.layer_dims(), vec![(16, 4)]);
        let two = ModelConfig::gcn(16, 8, 4, 2);
        assert_eq!(two.layer_dims(), vec![(16, 8), (8, 4)]);
    }
}
