//! AOT manifest parsing (`manifest.json`, written by `compile/aot.py`).
//!
//! The manifest pins the **flat input order** of every artifact — the
//! contract between jax's lowering and the Rust execute path. All input
//! assembly goes through [`ArtifactSpec::check_inputs`] so a shape or
//! order mismatch fails loudly instead of producing garbage numerics.

use crate::model::ModelConfig;
use crate::runtime::tensor::HostTensor;
use crate::util::json::Json;
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// One tensor in an artifact's signature.
#[derive(Clone, Debug, PartialEq)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String, // "f32" | "i32"
}

impl TensorSpec {
    fn from_json(j: &Json) -> Result<TensorSpec> {
        let shape = j
            .req_arr("shape")?
            .iter()
            .map(|d| d.as_usize().ok_or_else(|| anyhow::anyhow!("bad shape dim")))
            .collect::<Result<Vec<_>>>()?;
        Ok(TensorSpec {
            name: j.req_str("name")?.to_string(),
            shape,
            dtype: j.req_str("dtype")?.to_string(),
        })
    }

    pub fn matches(&self, t: &HostTensor) -> bool {
        t.shape() == self.shape.as_slice() && t.dtype_name() == self.dtype
    }
}

/// One compiled artifact: HLO file + signature.
#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: PathBuf,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

impl ArtifactSpec {
    /// Validate a candidate input list against the manifest order.
    pub fn check_inputs(&self, inputs: &[&HostTensor]) -> Result<()> {
        if inputs.len() != self.inputs.len() {
            bail!(
                "{}: expected {} inputs, got {}",
                self.name,
                self.inputs.len(),
                inputs.len()
            );
        }
        for (spec, t) in self.inputs.iter().zip(inputs) {
            if !spec.matches(t) {
                bail!(
                    "{}: input `{}` expects {:?} {}, got {:?} {}",
                    self.name,
                    spec.name,
                    spec.shape,
                    spec.dtype,
                    t.shape(),
                    t.dtype_name()
                );
            }
        }
        Ok(())
    }
}

/// The whole manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub n_rows: usize,
    pub n_cols: usize,
    pub model: Option<ModelConfig>,
    pub artifacts: BTreeMap<String, ArtifactSpec>,
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).with_context(|| format!("read {path:?}"))?;
        let j = Json::parse(&text).with_context(|| format!("parse {path:?}"))?;
        let mut artifacts = BTreeMap::new();
        match j.get("artifacts") {
            Some(Json::Obj(map)) => {
                for (name, a) in map {
                    let inputs = a
                        .req_arr("inputs")?
                        .iter()
                        .map(TensorSpec::from_json)
                        .collect::<Result<Vec<_>>>()?;
                    let outputs = a
                        .req_arr("outputs")?
                        .iter()
                        .map(TensorSpec::from_json)
                        .collect::<Result<Vec<_>>>()?;
                    artifacts.insert(
                        name.clone(),
                        ArtifactSpec {
                            name: name.clone(),
                            file: dir.join(a.req_str("file")?),
                            inputs,
                            outputs,
                        },
                    );
                }
            }
            _ => bail!("manifest has no artifacts object"),
        }
        let model = match j.get("model") {
            Some(m) => Some(ModelConfig::from_json(m)?),
            None => None,
        };
        Ok(Manifest {
            dir,
            n_rows: j.req_usize("n_rows")?,
            n_cols: j.req_usize("n_cols")?,
            model,
            artifacts,
        })
    }

    pub fn artifact(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("artifact `{name}` not in manifest ({:?})", self.artifacts.keys()))
    }

    /// Load the BELL bucket tensors referenced by an artifact's inputs
    /// (every input named `bell_*` maps to `<dir>/<name>.npy`).
    pub fn load_bell_inputs(&self, artifact: &str) -> Result<Vec<(String, HostTensor)>> {
        let spec = self.artifact(artifact)?;
        let mut out = Vec::new();
        for input in &spec.inputs {
            if input.name.starts_with("bell_") {
                let t = HostTensor::load_npy(self.dir.join(format!("{}.npy", input.name)))?;
                if !input.matches(&t) {
                    bail!("bell tensor {} shape mismatch", input.name);
                }
                out.push((input.name.clone(), t));
            }
        }
        Ok(out)
    }

    /// Load the initial parameters saved by aot.py.
    pub fn load_params(&self) -> Result<Vec<HostTensor>> {
        let model = self.model.as_ref().ok_or_else(|| anyhow::anyhow!("manifest has no model"))?;
        (0..model.n_params)
            .map(|i| HostTensor::load_npy(self.dir.join(format!("param_{i}.npy"))))
            .collect()
    }

    /// The SpMM artifact names and their column dims, ascending.
    pub fn spmm_coldims(&self) -> Vec<(usize, String)> {
        let mut v: Vec<(usize, String)> = self
            .artifacts
            .keys()
            .filter_map(|k| {
                k.strip_prefix("spmm_f").and_then(|d| d.parse::<usize>().ok()).map(|d| (d, k.clone()))
            })
            .collect();
        v.sort();
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_manifest(dir: &Path) {
        std::fs::create_dir_all(dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{
              "n_rows": 10, "n_cols": 10,
              "model": {"arch":"gcn","in_dim":4,"hidden_dim":4,"out_dim":2,"n_layers":1,"lr":0.1,"n_params":2},
              "artifacts": {
                "spmm_f16": {
                  "file": "spmm_f16.hlo.txt",
                  "inputs": [
                    {"name": "bell_w2_cols", "shape": [8, 2], "dtype": "i32"},
                    {"name": "x", "shape": [10, 16], "dtype": "f32"}
                  ],
                  "outputs": [{"name": "y", "shape": [10, 16], "dtype": "f32"}]
                },
                "spmm_f64": {
                  "file": "spmm_f64.hlo.txt",
                  "inputs": [], "outputs": []
                }
              }
            }"#,
        )
        .unwrap();
    }

    #[test]
    fn parse_and_validate() {
        let dir = std::env::temp_dir().join("accel_gcn_manifest_test");
        write_manifest(&dir);
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.n_rows, 10);
        assert_eq!(m.model.as_ref().unwrap().arch, "gcn");
        let a = m.artifact("spmm_f16").unwrap();
        assert_eq!(a.inputs.len(), 2);
        assert_eq!(m.spmm_coldims(), vec![(16, "spmm_f16".into()), (64, "spmm_f64".into())]);

        let cols = HostTensor::i32(&[8, 2], vec![0; 16]);
        let x = HostTensor::f32(&[10, 16], vec![0.0; 160]);
        assert!(a.check_inputs(&[&cols, &x]).is_ok());
        // wrong order
        assert!(a.check_inputs(&[&x, &cols]).is_err());
        // wrong arity
        assert!(a.check_inputs(&[&cols]).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn unknown_artifact_errors() {
        let dir = std::env::temp_dir().join("accel_gcn_manifest_test2");
        write_manifest(&dir);
        let m = Manifest::load(&dir).unwrap();
        assert!(m.artifact("nope").is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
