//! PJRT runtime: load AOT artifacts (HLO text), compile once, execute
//! from the Rust hot path. Python never runs here.
//!
//! * [`tensor`] — host-side tensors (`HostTensor`) bridging npy files,
//!   in-memory data, and `xla::Literal`s.
//! * [`artifacts`] — the AOT manifest (`manifest.json`): artifact →
//!   ordered input/output tensor specs.
//! * [`client`] — `Runtime`: PJRT CPU client + compiled-executable
//!   cache, with manifest-validated execution.
//!
//! `xla::PjRtClient` is `Rc`-backed (not `Send`): a `Runtime` must stay
//! on the thread that created it. The serving engine wraps it in a
//! dedicated device thread (see `coordinator::engine`).

pub mod tensor;
pub mod artifacts;
pub mod client;

pub use artifacts::{ArtifactSpec, Manifest, TensorSpec};
pub use client::Runtime;
pub use tensor::HostTensor;
