//! `Runtime`: the PJRT CPU client + compiled-executable cache.
//!
//! Pattern from /opt/xla-example/load_hlo: `HloModuleProto::from_text_file`
//! → `XlaComputation::from_proto` → `client.compile` → `execute`.
//! Artifacts lower with `return_tuple=True`, so every execution returns
//! one tuple literal which is decomposed into the manifest's outputs.
//!
//! NOT `Send` (PjRt handles are `Rc`-backed): construct and use on one
//! thread; `coordinator::engine` owns one per device thread.

use super::artifacts::{ArtifactSpec, Manifest};
use super::tensor::HostTensor;
use anyhow::{Context, Result};
use std::collections::HashMap;
use std::time::Instant;

/// A compiled artifact plus its signature.
pub struct Compiled {
    pub spec: ArtifactSpec,
    exe: xla::PjRtLoadedExecutable,
    /// compile wall-time, for the perf log
    pub compile_secs: f64,
}

/// PJRT client with an executable cache keyed by artifact name.
pub struct Runtime {
    client: xla::PjRtClient,
    cache: HashMap<String, Compiled>,
}

impl Runtime {
    /// Create a CPU runtime.
    pub fn cpu() -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        Ok(Runtime { client, cache: HashMap::new() })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch from cache) an artifact from the manifest.
    pub fn load(&mut self, manifest: &Manifest, name: &str) -> Result<&Compiled> {
        if !self.cache.contains_key(name) {
            let spec = manifest.artifact(name)?.clone();
            let t0 = Instant::now();
            let proto = xla::HloModuleProto::from_text_file(
                spec.file.to_str().context("artifact path not utf-8")?,
            )
            .with_context(|| format!("parse HLO text {:?}", spec.file))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .with_context(|| format!("compile artifact `{name}`"))?;
            let compile_secs = t0.elapsed().as_secs_f64();
            self.cache.insert(name.to_string(), Compiled { spec, exe, compile_secs });
        }
        Ok(&self.cache[name])
    }

    /// Execute a loaded artifact with manifest-ordered inputs.
    pub fn execute(&self, name: &str, inputs: &[&HostTensor]) -> Result<Vec<HostTensor>> {
        let compiled = self
            .cache
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("artifact `{name}` not loaded"))?;
        compiled.spec.check_inputs(inputs)?;
        let literals: Vec<xla::Literal> =
            inputs.iter().map(|t| t.to_literal()).collect::<Result<_>>()?;
        self.execute_literals(name, &literals)
    }

    /// Execute with pre-built literals (used by the engine's per-graph
    /// literal cache to avoid re-uploading static bucket tensors).
    pub fn execute_literals<L: std::borrow::Borrow<xla::Literal>>(
        &self,
        name: &str,
        inputs: &[L],
    ) -> Result<Vec<HostTensor>> {
        let compiled = self
            .cache
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("artifact `{name}` not loaded"))?;
        let result = compiled
            .exe
            .execute::<L>(inputs)
            .with_context(|| format!("execute `{name}`"))?;
        let tuple = result[0][0]
            .to_literal_sync()
            .context("fetch result literal")?;
        let parts = tuple.to_tuple().context("decompose result tuple")?;
        anyhow::ensure!(
            parts.len() == compiled.spec.outputs.len(),
            "`{name}`: got {} outputs, manifest says {}",
            parts.len(),
            compiled.spec.outputs.len()
        );
        parts.iter().map(HostTensor::from_literal).collect()
    }

    /// Is an artifact already compiled?
    pub fn is_loaded(&self, name: &str) -> bool {
        self.cache.contains_key(name)
    }

    pub fn loaded_artifacts(&self) -> Vec<&str> {
        self.cache.keys().map(|s| s.as_str()).collect()
    }
}

// Unit tests requiring the PJRT shared library live in
// rust/tests/runtime_roundtrip.rs (integration), so `cargo test --lib`
// stays fast and library-independent.
