//! Host tensors: the `Send`-able currency between the coordinator's
//! front end and the device thread, convertible to/from `xla::Literal`
//! and `.npy` files.

use crate::util::npy::{Dtype, Npy};
use anyhow::{bail, Result};

/// A dense host tensor (row-major).
#[derive(Clone, Debug, PartialEq)]
pub enum HostTensor {
    F32 { shape: Vec<usize>, data: Vec<f32> },
    I32 { shape: Vec<usize>, data: Vec<i32> },
}

impl HostTensor {
    pub fn f32(shape: &[usize], data: Vec<f32>) -> HostTensor {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        HostTensor::F32 { shape: shape.to_vec(), data }
    }

    pub fn i32(shape: &[usize], data: Vec<i32>) -> HostTensor {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        HostTensor::I32 { shape: shape.to_vec(), data }
    }

    pub fn zeros_f32(shape: &[usize]) -> HostTensor {
        HostTensor::F32 { shape: shape.to_vec(), data: vec![0.0; shape.iter().product()] }
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            HostTensor::F32 { shape, .. } | HostTensor::I32 { shape, .. } => shape,
        }
    }

    pub fn len(&self) -> usize {
        self.shape().iter().product()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn dtype_name(&self) -> &'static str {
        match self {
            HostTensor::F32 { .. } => "f32",
            HostTensor::I32 { .. } => "i32",
        }
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            HostTensor::F32 { data, .. } => Ok(data),
            _ => bail!("tensor is {}, not f32", self.dtype_name()),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            HostTensor::I32 { data, .. } => Ok(data),
            _ => bail!("tensor is {}, not i32", self.dtype_name()),
        }
    }

    /// Scalar extraction (rank-0 or single-element tensors).
    pub fn scalar_f32(&self) -> Result<f32> {
        let d = self.as_f32()?;
        if d.len() != 1 {
            bail!("tensor has {} elements, not 1", d.len());
        }
        Ok(d[0])
    }

    /// Build an `xla::Literal` (copies the data into XLA's buffer).
    pub fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<usize> = self.shape().to_vec();
        let lit = match self {
            HostTensor::F32 { data, .. } => {
                let bytes: &[u8] = unsafe {
                    std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4)
                };
                xla::Literal::create_from_shape_and_untyped_data(
                    xla::ElementType::F32,
                    &dims,
                    bytes,
                )?
            }
            HostTensor::I32 { data, .. } => {
                let bytes: &[u8] = unsafe {
                    std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4)
                };
                xla::Literal::create_from_shape_and_untyped_data(
                    xla::ElementType::S32,
                    &dims,
                    bytes,
                )?
            }
        };
        Ok(lit)
    }

    /// Read back from an `xla::Literal`.
    pub fn from_literal(lit: &xla::Literal) -> Result<HostTensor> {
        let shape = lit.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        match shape.ty() {
            xla::ElementType::F32 => Ok(HostTensor::F32 { shape: dims, data: lit.to_vec::<f32>()? }),
            xla::ElementType::S32 => Ok(HostTensor::I32 { shape: dims, data: lit.to_vec::<i32>()? }),
            other => bail!("unsupported literal element type {other:?}"),
        }
    }

    pub fn from_npy(npy: &Npy) -> Result<HostTensor> {
        match npy.dtype {
            Dtype::F32 => Ok(HostTensor::F32 { shape: npy.shape.clone(), data: npy.to_f32()? }),
            Dtype::I32 => Ok(HostTensor::I32 { shape: npy.shape.clone(), data: npy.to_i32()? }),
            Dtype::I64 => {
                // manifest tensors are i32/f32; i64 npy (e.g. row_ptr) narrows
                let data: Vec<i32> = npy.to_i64()?.into_iter().map(|v| v as i32).collect();
                Ok(HostTensor::I32 { shape: npy.shape.clone(), data })
            }
        }
    }

    pub fn load_npy(path: impl AsRef<std::path::Path>) -> Result<HostTensor> {
        Self::from_npy(&Npy::load(path)?)
    }

    pub fn to_npy(&self) -> Npy {
        match self {
            HostTensor::F32 { shape, data } => Npy::from_f32(shape, data),
            HostTensor::I32 { shape, data } => Npy::from_i32(shape, data),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accessors() {
        let t = HostTensor::f32(&[2, 3], vec![1.0; 6]);
        assert_eq!(t.shape(), &[2, 3]);
        assert_eq!(t.len(), 6);
        assert_eq!(t.dtype_name(), "f32");
        assert!(t.as_f32().is_ok());
        assert!(t.as_i32().is_err());
    }

    #[test]
    fn npy_roundtrip() {
        let t = HostTensor::i32(&[4], vec![1, -2, 3, 4]);
        let back = HostTensor::from_npy(&t.to_npy()).unwrap();
        assert_eq!(t, back);
    }

    #[test]
    fn i64_npy_narrows() {
        let npy = crate::util::npy::Npy::from_i64(&[2], &[7, 9]);
        let t = HostTensor::from_npy(&npy).unwrap();
        assert_eq!(t.as_i32().unwrap(), &[7, 9]);
    }

    #[test]
    fn scalar() {
        assert_eq!(HostTensor::f32(&[1], vec![3.5]).scalar_f32().unwrap(), 3.5);
        assert!(HostTensor::f32(&[2], vec![1.0, 2.0]).scalar_f32().is_err());
    }

    // literal round-trips are covered by the integration test
    // rust/tests/runtime_roundtrip.rs (they need the PJRT library loaded)
}
