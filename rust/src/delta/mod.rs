//! Dynamic graphs: batched edge updates with incremental plan
//! maintenance.
//!
//! Accel-GCN keeps preprocessing lightweight precisely so it stays
//! negligible next to execution — but a *frozen* pipeline still pays
//! the whole degree-sort → partition chain again for any topology
//! change. This subsystem makes graph evolution first-class:
//!
//! * [`graph`] — [`DeltaGraph`]: batched insertions/deletions staged in
//!   a per-row overlay over an immutable base CSR, with threshold
//!   compaction (see the module docs for the overlay semantics).
//! * [`patch`] — [`patch_plan`] / [`patch_identity_plan`]: rebuild only
//!   the degree buckets an update batch dirtied, structurally reusing
//!   every untouched block-metadata record and bulk-copying untouched
//!   sorted rows — validated bit-for-bit against
//!   [`SpmmPlan::build`](crate::pipeline::SpmmPlan::build).
//!
//! Consumers:
//! * [`pipeline::PlanCache`](crate::pipeline::PlanCache) gained
//!   per-key [`invalidate`](crate::pipeline::PlanCache::invalidate) and
//!   a [`refresh`](crate::pipeline::PlanCache::refresh) path that swaps
//!   a stale entry for a patched plan.
//! * [`serve`](crate::serve): tenants accept an `UpdateGraph` request
//!   kind; entries are epoch-versioned so in-flight requests finish on
//!   the old epoch while new requests pick up the patched plan.
//! * `bench --experiment delta_update` measures patch-vs-full-replan
//!   speedup across update-batch sizes × degree-skew regimes.

pub mod graph;
pub mod patch;

pub use graph::{ApplyReport, DeltaGraph, EdgeUpdate, RowChange, DEFAULT_COMPACT_FRAC};
pub use patch::{incremental_perm, invert_perm, patch_identity_plan, patch_plan, PatchStats};
