//! [`DeltaGraph`]: batched edge insertions/deletions layered over an
//! immutable base [`Csr`].
//!
//! ## Overlay / compaction model
//!
//! The base CSR is never mutated in place. Updates are staged into a
//! per-row overlay (`row → col → Some(weight) | None`), where `Some`
//! is an upsert (insert, or overwrite of an existing weight) and `None`
//! is a deletion of an edge present in the base. Reads merge the base
//! row with its overlay on the fly, so the effective matrix is always
//! well-defined without rewriting the CSR arrays per batch.
//!
//! When the overlay grows past `compact_frac × base.nnz()` staged
//! cells, [`DeltaGraph::apply`] rewrites the base CSR from the merged
//! view and clears the overlay — the same "preprocessing must stay
//! cheap relative to execution" trade the paper makes for degree
//! sorting, applied to graph evolution: small batches stay O(batch),
//! and the O(nnz) rewrite is amortized over many batches.
//!
//! Every [`DeltaGraph::apply`] returns the [`RowChange`] set (old and
//! new effective degree per touched row) that
//! [`patch_plan`](super::patch::patch_plan) consumes to rebuild only
//! the dirty degree buckets of an existing
//! [`SpmmPlan`](crate::pipeline::SpmmPlan).

use crate::graph::csr::Csr;
use anyhow::{bail, Result};
use std::collections::BTreeMap;

/// One staged topology change. `Insert` is an upsert: inserting an
/// edge that already exists replaces its weight. `Delete` of an absent
/// edge is a no-op.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum EdgeUpdate {
    Insert { row: u32, col: u32, val: f32 },
    Delete { row: u32, col: u32 },
}

impl EdgeUpdate {
    pub fn row(&self) -> u32 {
        match self {
            EdgeUpdate::Insert { row, .. } | EdgeUpdate::Delete { row, .. } => *row,
        }
    }

    pub fn col(&self) -> u32 {
        match self {
            EdgeUpdate::Insert { col, .. } | EdgeUpdate::Delete { col, .. } => *col,
        }
    }
}

/// One row whose effective adjacency changed in a batch: its degree
/// before and after. Rows with `old_deg == new_deg` changed content
/// (weights or column set of equal size) but keep their position in the
/// degree-sorted order.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RowChange {
    pub row: u32,
    pub old_deg: usize,
    pub new_deg: usize,
}

/// What one [`DeltaGraph::apply`] did.
#[derive(Clone, Debug)]
pub struct ApplyReport {
    /// Rows touched by this batch, ascending by row id, with effective
    /// degrees before and after the batch.
    pub changes: Vec<RowChange>,
    /// Updates staged by this batch (== the batch length).
    pub staged_ops: usize,
    /// Whether this apply crossed the compaction threshold and rewrote
    /// the base CSR.
    pub compacted: bool,
    /// Overlay cells resident after the apply (0 right after a
    /// compaction).
    pub overlay_cells: usize,
}

/// Per-row staged changes: `col → Some(weight)` upsert, `None` delete.
type RowOverlay = BTreeMap<u32, Option<f32>>;

/// A CSR matrix plus staged edge updates (see module docs).
#[derive(Clone, Debug)]
pub struct DeltaGraph {
    base: Csr,
    overlay: BTreeMap<u32, RowOverlay>,
    /// Total staged cells across rows (the compaction trigger).
    overlay_cells: usize,
    /// Effective nnz minus base nnz.
    nnz_delta: i64,
    compact_frac: f64,
    /// Base rewrites performed so far.
    pub compactions: u64,
}

/// Default compaction trigger: rewrite once the overlay holds more
/// than a quarter of the base's nonzeros.
pub const DEFAULT_COMPACT_FRAC: f64 = 0.25;

impl DeltaGraph {
    /// Wrap `base` with the default compaction threshold.
    pub fn new(base: Csr) -> DeltaGraph {
        DeltaGraph::with_threshold(base, DEFAULT_COMPACT_FRAC)
    }

    /// Wrap `base`, compacting once `overlay_cells > frac × base.nnz()`.
    /// `frac <= 0` compacts on every apply; very large `frac`
    /// effectively disables compaction.
    pub fn with_threshold(base: Csr, frac: f64) -> DeltaGraph {
        DeltaGraph {
            base,
            overlay: BTreeMap::new(),
            overlay_cells: 0,
            nnz_delta: 0,
            compact_frac: frac,
            compactions: 0,
        }
    }

    pub fn n_rows(&self) -> usize {
        self.base.n_rows
    }

    pub fn n_cols(&self) -> usize {
        self.base.n_cols
    }

    /// Effective stored nonzeros (base plus staged inserts minus staged
    /// deletes).
    pub fn nnz(&self) -> usize {
        (self.base.nnz() as i64 + self.nnz_delta) as usize
    }

    /// Staged overlay cells.
    pub fn overlay_len(&self) -> usize {
        self.overlay_cells
    }

    /// The immutable base snapshot (most recently compacted CSR).
    pub fn base(&self) -> &Csr {
        &self.base
    }

    /// Whether the base stores edge `(r, c)`.
    fn base_has(&self, r: u32, c: u32) -> bool {
        let span = self.base.row_ptr[r as usize]..self.base.row_ptr[r as usize + 1];
        self.base.col_idx[span].binary_search(&c).is_ok()
    }

    /// Effective degree of row `r` (base merged with overlay).
    pub fn degree(&self, r: usize) -> usize {
        let mut d = self.base.degree(r) as i64;
        if let Some(row) = self.overlay.get(&(r as u32)) {
            for (&c, cell) in row {
                match cell {
                    // upsert of a column absent from the base adds one
                    Some(_) if !self.base_has(r as u32, c) => d += 1,
                    // deletes are only staged for base-present columns
                    None => d -= 1,
                    _ => {}
                }
            }
        }
        d as usize
    }

    /// Effective row `r` as sorted `(col, val)` pairs.
    pub fn effective_row(&self, r: usize) -> Vec<(u32, f32)> {
        let mut out = Vec::with_capacity(self.base.degree(r));
        self.merge_row_into(r, &mut |c, v| out.push((c, v)));
        out
    }

    /// Two-pointer merge of base row `r` with its overlay, ascending by
    /// column; staged deletes suppress base entries, staged upserts
    /// replace or extend them.
    fn merge_row_into(&self, r: usize, emit: &mut impl FnMut(u32, f32)) {
        let span = self.base.row_ptr[r]..self.base.row_ptr[r + 1];
        let cols = &self.base.col_idx[span.clone()];
        let vals = &self.base.vals[span];
        match self.overlay.get(&(r as u32)) {
            None => {
                for (&c, &v) in cols.iter().zip(vals) {
                    emit(c, v);
                }
            }
            Some(ov) => {
                let mut i = 0usize;
                let mut it = ov.iter().peekable();
                loop {
                    match (cols.get(i), it.peek()) {
                        (Some(&bc), Some(&(&oc, cell))) => {
                            if bc < oc {
                                emit(bc, vals[i]);
                                i += 1;
                            } else if bc > oc {
                                if let Some(v) = cell {
                                    emit(oc, *v);
                                }
                                it.next();
                            } else {
                                // overlay wins on collision (upsert or delete)
                                if let Some(v) = cell {
                                    emit(bc, *v);
                                }
                                i += 1;
                                it.next();
                            }
                        }
                        (Some(&bc), None) => {
                            emit(bc, vals[i]);
                            i += 1;
                        }
                        (None, Some(&(&oc, cell))) => {
                            if let Some(v) = cell {
                                emit(oc, *v);
                            }
                            it.next();
                        }
                        (None, None) => break,
                    }
                }
            }
        }
    }

    /// The current effective matrix as a standalone canonical CSR
    /// (sorted columns, no duplicates). O(nnz + overlay).
    pub fn snapshot(&self) -> Csr {
        if self.overlay.is_empty() {
            return self.base.clone();
        }
        let n = self.base.n_rows;
        let mut row_ptr = Vec::with_capacity(n + 1);
        let mut col_idx = Vec::with_capacity(self.nnz());
        let mut vals = Vec::with_capacity(self.nnz());
        row_ptr.push(0);
        for r in 0..n {
            self.merge_row_into(r, &mut |c, v| {
                col_idx.push(c);
                vals.push(v);
            });
            row_ptr.push(col_idx.len());
        }
        Csr { n_rows: n, n_cols: self.base.n_cols, row_ptr, col_idx, vals }
    }

    /// Rewrite the base CSR from the merged view and clear the overlay.
    pub fn compact(&mut self) {
        if self.overlay.is_empty() {
            return;
        }
        self.base = self.snapshot();
        self.overlay.clear();
        self.overlay_cells = 0;
        self.nnz_delta = 0;
        self.compactions += 1;
    }

    /// Stage one update batch; compacts afterwards if the overlay
    /// crossed the threshold. Errors on out-of-bounds endpoints (the
    /// batch is rejected atomically — nothing is staged).
    pub fn apply(&mut self, updates: &[EdgeUpdate]) -> Result<ApplyReport> {
        for u in updates {
            let (r, c) = (u.row() as usize, u.col() as usize);
            if r >= self.base.n_rows || c >= self.base.n_cols {
                bail!(
                    "update ({r},{c}) out of bounds {}x{}",
                    self.base.n_rows,
                    self.base.n_cols
                );
            }
        }
        // effective degrees before staging, one entry per touched row
        let mut old_degs: BTreeMap<u32, usize> = BTreeMap::new();
        for u in updates {
            let r = u.row();
            old_degs.entry(r).or_insert_with(|| self.degree(r as usize));
        }
        for u in updates {
            self.stage(*u);
        }
        let changes: Vec<RowChange> = old_degs
            .into_iter()
            .map(|(row, old_deg)| RowChange { row, old_deg, new_deg: self.degree(row as usize) })
            .collect();
        let threshold = self.compact_frac * self.base.nnz().max(1) as f64;
        let compacted = self.overlay_cells as f64 > threshold;
        if compacted {
            self.compact();
        }
        Ok(ApplyReport {
            changes,
            staged_ops: updates.len(),
            compacted,
            overlay_cells: self.overlay_cells,
        })
    }

    fn stage(&mut self, u: EdgeUpdate) {
        let (r, c) = (u.row(), u.col());
        let base_has = self.base_has(r, c);
        let row = self.overlay.entry(r).or_default();
        match u {
            EdgeUpdate::Insert { val, .. } => {
                let prev = row.insert(c, Some(val));
                match prev {
                    Some(_) => {} // re-staged cell: cell count unchanged
                    None => self.overlay_cells += 1,
                }
                // effectively present before? (staged Some, or base and not staged-deleted)
                let was_present = matches!(prev, Some(Some(_))) || (prev.is_none() && base_has);
                if !was_present {
                    self.nnz_delta += 1;
                }
            }
            EdgeUpdate::Delete { .. } => {
                if base_has {
                    let prev = row.insert(c, None);
                    match prev {
                        Some(_) => {}
                        None => self.overlay_cells += 1,
                    }
                    let was_present = !matches!(prev, Some(None));
                    if was_present {
                        self.nnz_delta -= 1;
                    }
                } else {
                    // delete of a non-base edge: cancel any staged insert
                    // (a staged `None` cell cannot exist here — deletes
                    // are only staged for base-present columns)
                    if let Some(Some(_)) = row.remove(&c) {
                        self.overlay_cells -= 1;
                        self.nnz_delta -= 1;
                    }
                }
                if row.is_empty() {
                    self.overlay.remove(&r);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg;

    fn base() -> Csr {
        // 4x4: row0 = {0:1, 2:2}, row1 = {1:3}, row2 = {}, row3 = {0:4, 1:5, 3:6}
        Csr::from_edges(
            4,
            4,
            &[(0, 0, 1.0), (0, 2, 2.0), (1, 1, 3.0), (3, 0, 4.0), (3, 1, 5.0), (3, 3, 6.0)],
        )
        .unwrap()
    }

    #[test]
    fn insert_new_edge() {
        let mut dg = DeltaGraph::new(base());
        let rep = dg.apply(&[EdgeUpdate::Insert { row: 2, col: 3, val: 9.0 }]).unwrap();
        assert_eq!(rep.changes, vec![RowChange { row: 2, old_deg: 0, new_deg: 1 }]);
        assert_eq!(dg.nnz(), 7);
        assert_eq!(dg.degree(2), 1);
        assert_eq!(dg.effective_row(2), vec![(3, 9.0)]);
    }

    #[test]
    fn insert_overwrites_existing_weight() {
        let mut dg = DeltaGraph::new(base());
        let rep = dg.apply(&[EdgeUpdate::Insert { row: 0, col: 2, val: 7.5 }]).unwrap();
        assert_eq!(rep.changes, vec![RowChange { row: 0, old_deg: 2, new_deg: 2 }]);
        assert_eq!(dg.nnz(), 6, "upsert of an existing edge keeps nnz");
        assert_eq!(dg.effective_row(0), vec![(0, 1.0), (2, 7.5)]);
    }

    #[test]
    fn delete_existing_and_absent() {
        let mut dg = DeltaGraph::new(base());
        let rep = dg
            .apply(&[
                EdgeUpdate::Delete { row: 3, col: 1 },
                EdgeUpdate::Delete { row: 2, col: 2 }, // absent: no-op
            ])
            .unwrap();
        assert_eq!(dg.nnz(), 5);
        assert_eq!(dg.degree(3), 2);
        assert_eq!(dg.effective_row(3), vec![(0, 4.0), (3, 6.0)]);
        // both rows are reported touched (the no-op row with equal degrees)
        assert_eq!(rep.changes.len(), 2);
        assert_eq!(rep.changes[0], RowChange { row: 2, old_deg: 0, new_deg: 0 });
    }

    #[test]
    fn insert_then_delete_cancels() {
        let mut dg = DeltaGraph::with_threshold(base(), 1e9);
        dg.apply(&[EdgeUpdate::Insert { row: 2, col: 0, val: 1.0 }]).unwrap();
        dg.apply(&[EdgeUpdate::Delete { row: 2, col: 0 }]).unwrap();
        assert_eq!(dg.nnz(), 6);
        assert_eq!(dg.overlay_len(), 0, "cancelled cell is dropped");
        assert_eq!(dg.effective_row(2), vec![]);
    }

    #[test]
    fn delete_then_insert_restores() {
        let mut dg = DeltaGraph::with_threshold(base(), 1e9);
        dg.apply(&[EdgeUpdate::Delete { row: 0, col: 0 }]).unwrap();
        assert_eq!(dg.nnz(), 5);
        dg.apply(&[EdgeUpdate::Insert { row: 0, col: 0, val: 2.0 }]).unwrap();
        assert_eq!(dg.nnz(), 6);
        assert_eq!(dg.effective_row(0), vec![(0, 2.0), (2, 2.0)]);
    }

    #[test]
    fn snapshot_matches_expected_matrix() {
        let mut dg = DeltaGraph::with_threshold(base(), 1e9);
        dg.apply(&[
            EdgeUpdate::Insert { row: 2, col: 1, val: 8.0 },
            EdgeUpdate::Delete { row: 3, col: 3 },
            EdgeUpdate::Insert { row: 0, col: 2, val: -1.0 },
        ])
        .unwrap();
        let want = Csr::from_edges(
            4,
            4,
            &[(0, 0, 1.0), (0, 2, -1.0), (1, 1, 3.0), (2, 1, 8.0), (3, 0, 4.0), (3, 1, 5.0)],
        )
        .unwrap();
        assert_eq!(dg.snapshot(), want);
        assert_eq!(dg.nnz(), want.nnz());
    }

    #[test]
    fn compaction_triggers_and_preserves_matrix() {
        // threshold 0.25 over 6 nnz → compacts when overlay > 1.5 cells
        let mut dg = DeltaGraph::new(base());
        let r1 = dg.apply(&[EdgeUpdate::Insert { row: 2, col: 0, val: 1.0 }]).unwrap();
        assert!(!r1.compacted);
        let before = dg.snapshot();
        let r2 = dg
            .apply(&[
                EdgeUpdate::Insert { row: 2, col: 1, val: 2.0 },
                EdgeUpdate::Delete { row: 0, col: 0 },
            ])
            .unwrap();
        assert!(r2.compacted);
        assert_eq!(r2.overlay_cells, 0);
        assert_eq!(dg.compactions, 1);
        assert_eq!(dg.overlay_len(), 0);
        // compaction is invisible to the effective matrix
        let mut want_edges = vec![(2u32, 0u32, 1.0f32), (2, 1, 2.0)];
        for r in 0..4 {
            for (c, v) in before.row(r) {
                if !(r == 0 && c == 0) && !(r == 2 && c == 0) {
                    want_edges.push((r as u32, c, v));
                }
            }
        }
        let want = Csr::from_edges(4, 4, &want_edges).unwrap();
        assert_eq!(dg.snapshot(), want);
        assert_eq!(dg.base(), &want, "base rewritten in place");
    }

    #[test]
    fn out_of_bounds_rejected_atomically() {
        let mut dg = DeltaGraph::new(base());
        let err = dg.apply(&[
            EdgeUpdate::Insert { row: 0, col: 1, val: 1.0 },
            EdgeUpdate::Insert { row: 9, col: 0, val: 1.0 },
        ]);
        assert!(err.is_err());
        assert_eq!(dg.overlay_len(), 0, "failed batch stages nothing");
        assert_eq!(dg.snapshot(), base());
    }

    #[test]
    fn prop_random_batches_match_reference() {
        // staged view == matrix rebuilt from scratch after every batch
        crate::util::proptest::check("delta_graph_reference", 0xDE17A, 25, |rng| {
            let n = rng.range(1, 30);
            let mut edges = Vec::new();
            for r in 0..n {
                for _ in 0..rng.range(0, 6) {
                    edges.push((r as u32, rng.range(0, n) as u32, rng.f32() + 0.1));
                }
            }
            let base = Csr::from_edges(n, n, &edges).unwrap();
            let frac = *rng.choose(&[0.05, 0.5, 1e9]);
            let mut dg = DeltaGraph::with_threshold(base.clone(), frac);
            let mut reference = base;
            for _ in 0..rng.range(1, 5) {
                let batch: Vec<EdgeUpdate> = (0..rng.range(1, 12))
                    .map(|_| random_update(rng, &reference))
                    .collect();
                let rep = dg.apply(&batch).unwrap();
                reference = apply_reference(&reference, &batch);
                let snap = dg.snapshot();
                assert_eq!(snap, reference);
                assert_eq!(dg.nnz(), reference.nnz());
                for ch in &rep.changes {
                    assert_eq!(ch.new_deg, reference.degree(ch.row as usize));
                }
            }
        });
    }

    fn random_update(rng: &mut Pcg, cur: &Csr) -> EdgeUpdate {
        let n = cur.n_rows;
        if rng.f64() < 0.5 && cur.nnz() > 0 {
            // delete a (probably) existing edge
            let r = rng.range(0, n);
            if cur.degree(r) > 0 {
                let k = rng.range(0, cur.degree(r));
                let c = cur.col_idx[cur.row_ptr[r] + k];
                return EdgeUpdate::Delete { row: r as u32, col: c };
            }
        }
        EdgeUpdate::Insert {
            row: rng.range(0, n) as u32,
            col: rng.range(0, n) as u32,
            val: rng.f32() + 0.1,
        }
    }

    /// Oracle: replay updates against a dense map and rebuild.
    fn apply_reference(csr: &Csr, updates: &[EdgeUpdate]) -> Csr {
        let mut map: BTreeMap<(u32, u32), f32> = BTreeMap::new();
        for r in 0..csr.n_rows {
            for (c, v) in csr.row(r) {
                map.insert((r as u32, c), v);
            }
        }
        for u in updates {
            match *u {
                EdgeUpdate::Insert { row, col, val } => {
                    map.insert((row, col), val);
                }
                EdgeUpdate::Delete { row, col } => {
                    map.remove(&(row, col));
                }
            }
        }
        let edges: Vec<(u32, u32, f32)> = map.into_iter().map(|((r, c), v)| (r, c, v)).collect();
        Csr::from_edges(csr.n_rows, csr.n_cols, &edges).unwrap()
    }
}
