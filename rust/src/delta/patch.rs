//! Incremental [`SpmmPlan`] maintenance: rebuild only what an update
//! batch dirtied, reuse the rest **bit-for-bit**.
//!
//! ## Why a patch can be exact
//!
//! Every stage of the preprocessing chain is deterministic and local in
//! the degree-sorted domain:
//!
//! * The degree sort is a *stable* count sort, so the sorted order is
//!   fully determined by the degree multiset: within one degree bucket,
//!   rows appear in ascending original id. An update batch that changes
//!   the degrees of `k` rows therefore only reshuffles the buckets those
//!   degrees touch — every other row keeps its position, and
//!   [`incremental_perm`] reproduces the from-scratch permutation with a
//!   `O(affected + k log k)` merge instead of a full re-sort.
//! * Block metadata (Algorithm 2) never spans a degree boundary, and
//!   within a bucket every `loc` is `bucket_nz_start + offset` — so an
//!   untouched bucket's records are the from-scratch records shifted by
//!   two constants (`row` by the bucket's new start row, `loc` by its
//!   new nonzero offset). [`patch_plan`] copies those records and runs
//!   Algorithm 2 only over buckets whose membership changed.
//! * The sorted CSR arrays of untouched rows are verbatim slices of the
//!   old sorted arrays; the splice coalesces consecutive unmoved rows
//!   into single bulk copies (one `memcpy` per surviving bucket run)
//!   instead of the per-row gather a full `permute_rows` pays.
//!
//! The tests assert *equality* (not closeness) of the patched plan's
//! permutation, sorted CSR, and block metadata against
//! [`SpmmPlan::build`] on the updated matrix — the patch is an
//! optimization, never a semantic fork.

use super::graph::RowChange;
use crate::graph::csr::Csr;
use crate::graph::degree::DegreeSorted;
use crate::partition::block_level::BlockPartition;
use crate::partition::metadata::BlockMeta;
use crate::partition::patterns::{PartitionParams, PatternTable};
use crate::partition::warp_level::WarpPartition;
use crate::pipeline::{GraphFingerprint, SpmmPlan};
use anyhow::{ensure, Result};
use std::collections::BTreeSet;

/// What a patch rebuilt vs reused.
#[derive(Clone, Copy, Debug, Default)]
pub struct PatchStats {
    /// Rows whose adjacency content changed.
    pub rows_changed: usize,
    /// Subset whose degree changed (these move in the sorted order).
    pub rows_moved: usize,
    /// Block-metadata records copied from the old plan (shifted).
    pub blocks_reused: usize,
    /// Block-metadata records re-derived via Algorithm 2.
    pub blocks_rebuilt: usize,
    pub nnz_before: usize,
    pub nnz_after: usize,
}

impl PatchStats {
    /// Fraction of block metadata reused structurally.
    pub fn reuse_frac(&self) -> f64 {
        let total = self.blocks_reused + self.blocks_rebuilt;
        if total == 0 {
            return 1.0;
        }
        self.blocks_reused as f64 / total as f64
    }
}

/// First index in `0..n` for which `pred` flips to false (degrees are
/// ascending, so bucket boundaries binary-search).
fn partition_point(n: usize, pred: impl Fn(usize) -> bool) -> usize {
    let (mut lo, mut hi) = (0usize, n);
    while lo < hi {
        let mid = lo + (hi - lo) / 2;
        if pred(mid) {
            lo = mid + 1;
        } else {
            hi = mid;
        }
    }
    lo
}

/// The incremental degree re-bucketing step: produce the stable
/// degree-sort permutation of the updated graph from the old
/// permutation plus the per-row degree changes, re-sorting only the
/// affected degree range.
///
/// `old_sorted_row_ptr` is the old *sorted* row pointer (its ascending
/// diffs are the old degrees). Exactness argument in the module docs;
/// the property tests compare against [`DegreeSorted::new`].
pub fn incremental_perm(
    old_perm: &[u32],
    old_sorted_row_ptr: &[usize],
    changes: &[RowChange],
) -> Vec<u32> {
    let moved: Vec<&RowChange> = changes.iter().filter(|c| c.old_deg != c.new_deg).collect();
    if moved.is_empty() {
        return old_perm.to_vec();
    }
    let n = old_perm.len();
    let old_deg_at = |i: usize| old_sorted_row_ptr[i + 1] - old_sorted_row_ptr[i];
    let lo = moved.iter().map(|c| c.old_deg.min(c.new_deg)).min().unwrap();
    let hi = moved.iter().map(|c| c.old_deg.max(c.new_deg)).max().unwrap();
    // [p, s) = the affected degree range in both old and new orders:
    // the degree multiset outside [lo, hi] is unchanged, so both
    // boundaries are shared
    let p = partition_point(n, |i| old_deg_at(i) < lo);
    let s = partition_point(n, |i| old_deg_at(i) <= hi);
    let mut moved_rows: Vec<u32> = moved.iter().map(|c| c.row).collect();
    moved_rows.sort_unstable();
    // rows entering the merge, ascending by (new_deg, original id) —
    // exactly the stable count sort's key
    let mut incoming: Vec<(usize, u32)> = moved.iter().map(|c| (c.new_deg, c.row)).collect();
    incoming.sort_unstable();

    let mut out = Vec::with_capacity(n);
    out.extend_from_slice(&old_perm[..p]);
    let mut it = incoming.into_iter().peekable();
    for i in p..s {
        let r = old_perm[i];
        if moved_rows.binary_search(&r).is_ok() {
            continue; // re-inserted from `incoming` at its new position
        }
        let key = (old_deg_at(i), r); // unchanged row: old key == new key
        while let Some(&(nd, nr)) = it.peek() {
            if (nd, nr) < key {
                out.push(nr);
                it.next();
            } else {
                break;
            }
        }
        out.push(r);
    }
    for (_, nr) in it {
        out.push(nr);
    }
    out.extend_from_slice(&old_perm[s..]);
    debug_assert_eq!(out.len(), n);
    out
}

/// `inv[perm[i]] == i`. Shared with the serve registry's update path.
pub fn invert_perm(perm: &[u32]) -> Vec<u32> {
    let mut inv = vec![0u32; perm.len()];
    for (i, &p) in perm.iter().enumerate() {
        inv[p as usize] = i as u32;
    }
    inv
}

/// Build the new sorted CSR by splicing: unmoved, content-clean rows
/// are bulk-copied from the old sorted arrays (runs of consecutive
/// survivors coalesce into single copies); dirty rows are taken from
/// `new_csr`.
fn splice_sorted(
    old: &SpmmPlan,
    new_csr: &Csr,
    perm_new: &[u32],
    dirty_rows: &[u32], // ascending original ids with changed content
) -> Csr {
    let n = new_csr.n_rows;
    let mut row_ptr = Vec::with_capacity(n + 1);
    row_ptr.push(0usize);
    let mut total = 0usize;
    for &r in perm_new {
        total += new_csr.degree(r as usize);
        row_ptr.push(total);
    }
    let mut col_idx: Vec<u32> = Vec::with_capacity(total);
    let mut vals: Vec<f32> = Vec::with_capacity(total);
    let old_csr = &old.sorted.csr;
    let inv_old = &old.sorted.inv;

    fn flush(cols: &mut Vec<u32>, vals: &mut Vec<f32>, src: &Csr, run: &mut Option<(usize, usize)>) {
        if let Some((s, e)) = run.take() {
            cols.extend_from_slice(&src.col_idx[s..e]);
            vals.extend_from_slice(&src.vals[s..e]);
        }
    }

    let mut run: Option<(usize, usize)> = None;
    for &r in perm_new {
        if dirty_rows.binary_search(&r).is_err() {
            let j = inv_old[r as usize] as usize;
            let (s, e) = (old_csr.row_ptr[j], old_csr.row_ptr[j + 1]);
            match run {
                Some((rs, re)) if re == s => run = Some((rs, e)),
                _ => {
                    flush(&mut col_idx, &mut vals, old_csr, &mut run);
                    run = Some((s, e));
                }
            }
        } else {
            flush(&mut col_idx, &mut vals, old_csr, &mut run);
            let span = new_csr.row_ptr[r as usize]..new_csr.row_ptr[r as usize + 1];
            col_idx.extend_from_slice(&new_csr.col_idx[span.clone()]);
            vals.extend_from_slice(&new_csr.vals[span]);
        }
    }
    flush(&mut col_idx, &mut vals, old_csr, &mut run);
    debug_assert_eq!(col_idx.len(), total);
    Csr { n_rows: n, n_cols: new_csr.n_cols, row_ptr, col_idx, vals }
}

/// One degree bucket's span in the old metadata vector.
struct OldBucket {
    meta_lo: usize,
    meta_hi: usize,
    start_row: u32,
    nz_start: u32,
}

/// Rebuild the block partition of `new_sorted`, copying (shifted) the
/// metadata of every degree bucket not in `changed_degs` and running
/// Algorithm 2 only over changed buckets. Returns the partition plus
/// (reused, rebuilt) record counts.
fn patch_block_partition(
    old: &BlockPartition,
    new_sorted: &Csr,
    changed_degs: &BTreeSet<usize>,
    params: PartitionParams,
) -> (BlockPartition, usize, usize) {
    debug_assert_eq!(old.params, params, "patch must keep the partition tunables");
    // index the old metadata by degree: records are ascending by row,
    // so each degree's records are one contiguous slice
    let mut old_buckets: Vec<(u32, OldBucket)> = Vec::new();
    let mut i = 0usize;
    while i < old.meta.len() {
        let d = old.meta[i].deg;
        let mut j = i + 1;
        while j < old.meta.len() && old.meta[j].deg == d {
            j += 1;
        }
        old_buckets.push((
            d,
            OldBucket {
                meta_lo: i,
                meta_hi: j,
                start_row: old.meta[i].row,
                nz_start: old.meta[i].loc,
            },
        ));
        i = j;
    }

    let n = new_sorted.n_rows;
    let deg_bound = params.deg_bound();
    let table = PatternTable::build(params);
    let deg_at = |i: usize| new_sorted.row_ptr[i + 1] - new_sorted.row_ptr[i];
    let mut meta: Vec<BlockMeta> = Vec::with_capacity(old.meta.len());
    let (mut reused, mut rebuilt) = (0usize, 0usize);

    let mut r = 0usize;
    while r < n {
        let d = deg_at(r);
        let mut end = r + 1;
        while end < n && deg_at(end) == d {
            end += 1;
        }
        if d == 0 {
            r = end; // zero rows produce no metadata
            continue;
        }
        let reusable = !changed_degs.contains(&d);
        if reusable {
            if let Ok(k) = old_buckets.binary_search_by_key(&(d as u32), |(deg, _)| *deg) {
                let b = &old_buckets[k].1;
                let row_shift = r as i64 - b.start_row as i64;
                let loc_shift = new_sorted.row_ptr[r] as i64 - b.nz_start as i64;
                for m in &old.meta[b.meta_lo..b.meta_hi] {
                    meta.push(BlockMeta {
                        deg: m.deg,
                        loc: (m.loc as i64 + loc_shift) as u32,
                        row: (m.row as i64 + row_shift) as u32,
                        info: m.info,
                    });
                }
                reused += b.meta_hi - b.meta_lo;
                r = end;
                continue;
            }
            // an unchanged degree absent from the old index cannot gain
            // rows; fall through defensively rather than panic
        }
        rebuilt += emit_bucket(&mut meta, &table, deg_bound, new_sorted, d, r, end);
        r = end;
    }

    // degrees ascend, so split rows are exactly the tail past deg_bound
    let n_split_rows = n - partition_point(n, |i| deg_at(i) <= deg_bound);
    (
        BlockPartition {
            params,
            meta,
            n_rows: n,
            nnz: new_sorted.nnz(),
            n_split_rows,
        },
        reused,
        rebuilt,
    )
}

/// Algorithm 2 restricted to one degree bucket `[r, end)` — mirrors
/// [`BlockPartition::build`]'s two branches record-for-record.
fn emit_bucket(
    meta: &mut Vec<BlockMeta>,
    table: &PatternTable,
    deg_bound: usize,
    sorted: &Csr,
    d: usize,
    r: usize,
    end: usize,
) -> usize {
    let start_len = meta.len();
    if d <= deg_bound {
        let pattern = table.get(d);
        let mut rows_remaining = end - r;
        let mut row = r;
        while rows_remaining > 0 {
            let take = rows_remaining.min(pattern.block_rows);
            meta.push(BlockMeta {
                deg: d as u32,
                loc: sorted.row_ptr[row] as u32,
                row: row as u32,
                info: BlockMeta::pack_info(pattern.warp_nzs, take),
            });
            row += take;
            rows_remaining -= take;
        }
    } else {
        for rr in r..end {
            let mut deg_remaining = d;
            let mut loc = sorted.row_ptr[rr];
            while deg_remaining > 0 {
                let take = deg_remaining.min(deg_bound);
                meta.push(BlockMeta { deg: d as u32, loc: loc as u32, row: rr as u32, info: take as u32 });
                loc += take;
                deg_remaining -= take;
            }
        }
    }
    meta.len() - start_len
}

/// Validate that `changes` is consistent with both endpoints of the
/// patch (`old` plan state and `new` matrix). O(k).
fn check_changes(old_original: &Csr, new_original: &Csr, changes: &[RowChange]) -> Result<()> {
    ensure!(
        old_original.n_rows == new_original.n_rows && old_original.n_cols == new_original.n_cols,
        "patch cannot change matrix shape ({}x{} -> {}x{})",
        old_original.n_rows,
        old_original.n_cols,
        new_original.n_rows,
        new_original.n_cols
    );
    for c in changes {
        ensure!((c.row as usize) < old_original.n_rows, "change row {} out of bounds", c.row);
        ensure!(
            old_original.degree(c.row as usize) == c.old_deg,
            "change row {}: old_deg {} does not match the plan's matrix ({})",
            c.row,
            c.old_deg,
            old_original.degree(c.row as usize)
        );
        ensure!(
            new_original.degree(c.row as usize) == c.new_deg,
            "change row {}: new_deg {} does not match the updated matrix ({})",
            c.row,
            c.new_deg,
            new_original.degree(c.row as usize)
        );
    }
    Ok(())
}

fn changed_degree_set(changes: &[RowChange]) -> BTreeSet<usize> {
    changes
        .iter()
        .filter(|c| c.old_deg != c.new_deg)
        .flat_map(|c| [c.old_deg, c.new_deg])
        .collect()
}

fn sorted_dirty_rows(changes: &[RowChange]) -> Vec<u32> {
    let mut rows: Vec<u32> = changes.iter().map(|c| c.row).collect();
    rows.sort_unstable();
    rows.dedup();
    rows
}

/// Patch an [`SpmmPlan`] for an updated matrix. `changes` must describe
/// exactly the rows whose adjacency differs between `old.original` and
/// `new_original` (what [`DeltaGraph::apply`](super::DeltaGraph::apply)
/// reports); rows outside `changes` are assumed — and in tests
/// verified — to be identical.
///
/// The result is equal (same permutation, same sorted CSR, same block
/// metadata) to `SpmmPlan::build(new_original, old.params)`.
pub fn patch_plan(
    old: &SpmmPlan,
    new_original: Csr,
    changes: &[RowChange],
) -> Result<(SpmmPlan, PatchStats)> {
    check_changes(&old.original, &new_original, changes)?;
    let params = old.params;
    let perm_new = incremental_perm(&old.sorted.perm, &old.sorted.csr.row_ptr, changes);
    let inv_new = invert_perm(&perm_new);
    let dirty = sorted_dirty_rows(changes);
    let sorted_csr = splice_sorted(old, &new_original, &perm_new, &dirty);
    let changed_degs = changed_degree_set(changes);
    let (block, reused, rebuilt) =
        patch_block_partition(&old.block, &sorted_csr, &changed_degs, params);
    let warp = WarpPartition::build(&new_original, params.max_warp_nzs);
    let stats = PatchStats {
        rows_changed: dirty.len(),
        rows_moved: changes.iter().filter(|c| c.old_deg != c.new_deg).count(),
        blocks_reused: reused,
        blocks_rebuilt: rebuilt,
        nnz_before: old.nnz(),
        nnz_after: new_original.nnz(),
    };
    let sorted = DegreeSorted { csr: sorted_csr, perm: perm_new, inv: inv_new };
    Ok((SpmmPlan::from_parts(new_original, sorted, block, warp, params), stats))
}

/// Patch a plan built from a **relabeled** matrix (identity degree
/// sort — the native-serving case, see `serve::registry`). The caller
/// supplies the already-relabeled updated matrix; only the block
/// metadata is patched structurally (the identity sort makes the
/// "sorted" arrays the matrix itself), and the known fingerprint is
/// seeded so the plan cache never re-hashes it.
pub fn patch_identity_plan(
    old: &SpmmPlan,
    relabeled_new: &Csr,
    changes: &[RowChange],
    fingerprint: Option<GraphFingerprint>,
) -> Result<(SpmmPlan, PatchStats)> {
    let n = relabeled_new.n_rows;
    ensure!(
        old.n_rows() == n && old.original.n_cols == relabeled_new.n_cols,
        "identity patch cannot change matrix shape"
    );
    ensure!(
        old.sorted.perm.iter().enumerate().all(|(i, &p)| p as usize == i),
        "patch_identity_plan requires an identity-sorted plan"
    );
    debug_assert!(
        (1..n).all(|r| relabeled_new.degree(r - 1) <= relabeled_new.degree(r)),
        "relabeled matrix must be degree-ascending"
    );
    let params = old.params;
    let changed_degs = changed_degree_set(changes);
    let (block, reused, rebuilt) =
        patch_block_partition(&old.block, relabeled_new, &changed_degs, params);
    let warp = WarpPartition::build(relabeled_new, params.max_warp_nzs);
    let identity: Vec<u32> = (0..n as u32).collect();
    let sorted = DegreeSorted {
        csr: relabeled_new.clone(),
        perm: identity.clone(),
        inv: identity,
    };
    let stats = PatchStats {
        rows_changed: sorted_dirty_rows(changes).len(),
        rows_moved: changes.iter().filter(|c| c.old_deg != c.new_deg).count(),
        blocks_reused: reused,
        blocks_rebuilt: rebuilt,
        nnz_before: old.nnz(),
        nnz_after: relabeled_new.nnz(),
    };
    let plan = SpmmPlan::from_parts(relabeled_new.clone(), sorted, block, warp, params);
    if let Some(fp) = fingerprint {
        plan.seed_fingerprint(fp);
    }
    Ok((plan, stats))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::delta::graph::{DeltaGraph, EdgeUpdate};
    use crate::pipeline::spmm_block_level_parallel;
    use crate::spmm::verify::assert_allclose;
    use crate::util::proptest;
    use crate::util::rng::Pcg;
    use crate::util::threadpool::ThreadPool;
    use std::sync::Arc;

    fn random_csr(rng: &mut Pcg, n: usize, heavy_frac: f64) -> Csr {
        let mut edges = vec![(0u32, 0u32, 1.0f32)];
        for r in 0..n {
            let d = if rng.f64() < heavy_frac { rng.range(0, n) } else { rng.range(0, 7) };
            for _ in 0..d {
                edges.push((r as u32, rng.range(0, n) as u32, rng.f32() + 0.1));
            }
        }
        Csr::from_edges(n, n, &edges).unwrap()
    }

    fn random_batch(rng: &mut Pcg, cur: &Csr, k: usize) -> Vec<EdgeUpdate> {
        (0..k)
            .map(|_| {
                let n = cur.n_rows;
                if rng.f64() < 0.45 {
                    let r = rng.range(0, n);
                    if cur.degree(r) > 0 {
                        let i = cur.row_ptr[r] + rng.range(0, cur.degree(r));
                        return EdgeUpdate::Delete { row: r as u32, col: cur.col_idx[i] };
                    }
                }
                EdgeUpdate::Insert {
                    row: rng.range(0, n) as u32,
                    col: rng.range(0, n) as u32,
                    val: rng.f32() + 0.1,
                }
            })
            .collect()
    }

    fn assert_plans_identical(patched: &SpmmPlan, rebuilt: &SpmmPlan) {
        assert_eq!(patched.sorted.perm, rebuilt.sorted.perm, "permutation");
        assert_eq!(patched.sorted.inv, rebuilt.sorted.inv, "inverse permutation");
        assert_eq!(patched.sorted.csr, rebuilt.sorted.csr, "sorted CSR");
        assert_eq!(patched.block.meta, rebuilt.block.meta, "block metadata");
        assert_eq!(patched.block.n_split_rows, rebuilt.block.n_split_rows, "split rows");
        assert_eq!(patched.block.nnz, rebuilt.block.nnz);
        assert_eq!(patched.warp.groups, rebuilt.warp.groups, "warp groups");
        // the patch path must re-run per-bucket kernel selection: a
        // batch can move rows across the dense/sparse crossover, and
        // the patched schedule must match a from-scratch rebuild's
        assert_eq!(patched.kernels, rebuilt.kernels, "kernel schedule");
        assert_eq!(patched.original, rebuilt.original, "original CSR");
    }

    #[test]
    fn prop_incremental_perm_matches_full_sort() {
        proptest::check("delta_incremental_perm", 0x9E12B, 30, |rng| {
            let n = rng.range(2, 80);
            let base = random_csr(rng, n, 0.08);
            let mut dg = DeltaGraph::with_threshold(base.clone(), 1e9);
            let old = DegreeSorted::new(&base);
            let batch = random_batch(rng, &base, rng.range(1, 14));
            let rep = dg.apply(&batch).unwrap();
            let new_csr = dg.snapshot();
            let perm = incremental_perm(&old.perm, &old.csr.row_ptr, &rep.changes);
            assert_eq!(perm, DegreeSorted::new(&new_csr).perm);
        });
    }

    #[test]
    fn prop_patched_plan_identical_to_rebuild() {
        proptest::check("delta_patch_bitexact", 0xB17EC, 20, |rng| {
            let n = rng.range(2, 70);
            let params = PartitionParams {
                max_block_warps: *rng.choose(&[1usize, 2, 4, 12]),
                max_warp_nzs: *rng.choose(&[1usize, 2, 8, 32]),
            };
            let base = random_csr(rng, n, 0.08);
            let mut dg = DeltaGraph::with_threshold(base.clone(), *rng.choose(&[0.05, 1e9]));
            let mut plan = SpmmPlan::build(base, params);
            for _ in 0..rng.range(1, 4) {
                let batch = random_batch(rng, &dg.snapshot(), rng.range(1, 10));
                let rep = dg.apply(&batch).unwrap();
                let new_csr = dg.snapshot();
                let (patched, stats) = patch_plan(&plan, new_csr.clone(), &rep.changes).unwrap();
                let rebuilt = SpmmPlan::build(new_csr, params);
                assert_plans_identical(&patched, &rebuilt);
                assert_eq!(stats.blocks_reused + stats.blocks_rebuilt, rebuilt.block.meta.len());
                plan = patched; // chain: next batch patches the patched plan
            }
        });
    }

    /// The satellite property: for random base graphs × random
    /// insert/delete batches, DeltaGraph compaction + PlanPatch produce
    /// a plan whose SpMM output matches both the from-scratch plan and
    /// `Csr::spmm_dense`, across thread counts {1, 2, 8}.
    #[test]
    fn prop_patched_spmm_matches_dense_and_rebuild() {
        proptest::check("delta_patch_spmm", 0x5B33D, 8, |rng| {
            let n = rng.range(2, 50);
            let base = random_csr(rng, n, 0.1);
            // small threshold so compaction paths are exercised
            let mut dg = DeltaGraph::with_threshold(base.clone(), 0.1);
            let mut plan = Arc::new(SpmmPlan::build(base, PartitionParams::default()));
            for _ in 0..2 {
                let batch = random_batch(rng, &dg.snapshot(), rng.range(1, 12));
                let rep = dg.apply(&batch).unwrap();
                let new_csr = dg.snapshot();
                let (patched, _) = patch_plan(&plan, new_csr.clone(), &rep.changes).unwrap();
                let patched = Arc::new(patched);
                let rebuilt = Arc::new(SpmmPlan::build(new_csr.clone(), PartitionParams::default()));
                let f = rng.range(1, 6);
                let x: Vec<f32> = (0..n * f).map(|_| rng.f32() - 0.5).collect();
                let want = new_csr.spmm_dense(&x, f);
                for &threads in &[1usize, 2, 8] {
                    let pool = ThreadPool::new(threads);
                    // the parallel executor returns original row order
                    // directly (fused unpermute-scatter)
                    let got = spmm_block_level_parallel(&patched, &x, f, &pool);
                    let reb = spmm_block_level_parallel(&rebuilt, &x, f, &pool);
                    assert_allclose(&got, &want, 1e-4, 1e-4, "patched vs dense");
                    assert_allclose(&got, &reb, 1e-5, 1e-5, "patched vs rebuilt");
                }
                plan = patched;
            }
        });
    }

    #[test]
    fn empty_batch_patch_is_identity() {
        let mut rng = Pcg::seed_from(7);
        let base = random_csr(&mut rng, 40, 0.1);
        let plan = SpmmPlan::build(base.clone(), PartitionParams::default());
        let (patched, stats) = patch_plan(&plan, base, &[]).unwrap();
        assert_plans_identical(&patched, &plan);
        assert_eq!(stats.rows_changed, 0);
        assert_eq!(stats.blocks_rebuilt, 0);
        assert!((stats.reuse_frac() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn value_only_change_reuses_all_metadata() {
        // overwrite an existing edge's weight: no degree changes, so
        // every metadata record must be structurally reused
        let mut rng = Pcg::seed_from(8);
        let base = random_csr(&mut rng, 30, 0.1);
        let (r, c) = (0u32, base.col_idx[0]);
        let mut dg = DeltaGraph::with_threshold(base.clone(), 1e9);
        let rep = dg.apply(&[EdgeUpdate::Insert { row: r, col: c, val: 99.0 }]).unwrap();
        let plan = SpmmPlan::build(base, PartitionParams::default());
        let new_csr = dg.snapshot();
        let (patched, stats) = patch_plan(&plan, new_csr.clone(), &rep.changes).unwrap();
        assert_eq!(stats.rows_moved, 0);
        assert_eq!(stats.blocks_rebuilt, 0);
        assert_plans_identical(&patched, &SpmmPlan::build(new_csr, PartitionParams::default()));
    }

    #[test]
    fn stale_changes_rejected() {
        let mut rng = Pcg::seed_from(9);
        let base = random_csr(&mut rng, 20, 0.0);
        let plan = SpmmPlan::build(base.clone(), PartitionParams::default());
        // claim row 0 went from degree 5 to 6 — inconsistent with both
        let bogus = [RowChange { row: 0, old_deg: plan.original.degree(0) + 1, new_deg: 6 }];
        assert!(patch_plan(&plan, base, &bogus).is_err());
    }

    #[test]
    fn prop_identity_patch_matches_rebuild() {
        proptest::check("delta_identity_patch", 0x1DE47, 12, |rng| {
            let n = rng.range(2, 50);
            let base = random_csr(rng, n, 0.1);
            let mut dg = DeltaGraph::with_threshold(base.clone(), 1e9);
            // relabeled old matrix + identity plan (the serve shape)
            let ds = DegreeSorted::new(&base);
            let relabeled_old = base.relabel(&ds.perm, &ds.inv);
            let plan = SpmmPlan::build(relabeled_old, PartitionParams::default());
            let batch = random_batch(rng, &base, rng.range(1, 10));
            let rep = dg.apply(&batch).unwrap();
            let new_csr = dg.snapshot();
            let ds_new = DegreeSorted::new(&new_csr);
            let relabeled_new = new_csr.relabel(&ds_new.perm, &ds_new.inv);
            let (patched, _) =
                patch_identity_plan(&plan, &relabeled_new, &rep.changes, None).unwrap();
            let rebuilt = SpmmPlan::build(relabeled_new, PartitionParams::default());
            assert_plans_identical(&patched, &rebuilt);
        });
    }
}
