//! Reverse-mode differentiation of the GCN stack — every op of the
//! forward, transposed, on the same parallel machinery.
//!
//! For layer `l` with forward `H_l = act(Â·H_{l-1}·W_l + b_l)` and
//! incoming gradient `G = dL/dA_l` (the affine output):
//!
//! ```text
//! dW_l = Z_lᵀ · G              (dense GEMM, row-sharded + reduced)
//! db_l = Σ_rows G              (same sharding)
//! dZ_l = G · W_lᵀ              (dense GEMM, row-parallel)
//! dH_{l-1} = Âᵀ · dZ_l         (SpMM against the TRANSPOSED plan)
//! dA_{l-1} = dH_{l-1} ⊙ 1[H_{l-1} > 0]   (ReLU backward)
//! ```
//!
//! The transpose SpMM runs through the identical block-level schedule
//! as the forward — Accel-GCN's partition applies to `Âᵀ` exactly as to
//! `Â` (and when `Â` is symmetric the two plans are literally the same
//! object, see [`Trainer`](crate::train::Trainer)). The dense GEMMs
//! shard rows across the [`ThreadPool`] with scoped jobs: `dZ` rows are
//! disjoint output spans (lock-free), while `dW`/`db` accumulate into
//! per-shard buffers reduced **in shard order** after the join — the
//! same determinism discipline as the SpMM split-row reduction.

use crate::pipeline::{spmm_block_level_parallel_into, SpmmPlan};
use crate::serve::gcn::GcnModel;
use crate::train::tape::Tape;
use crate::train::PhaseBreakdown;
use crate::util::threadpool::ThreadPool;
use std::time::Instant;

/// Parameter gradients of one backward pass (plus `dL/dX` when
/// requested — the training loop skips it, the gradient check needs it).
#[derive(Clone, Debug)]
pub struct Gradients {
    /// `dw[l]` is `[din × dout]` row-major, like `model.weights[l]`.
    pub dw: Vec<Vec<f32>>,
    /// `db[l]` is `[dout]`.
    pub db: Vec<Vec<f32>>,
    /// `dL/dX` (`[n × in_dim]`), empty unless `want_dx`.
    pub dx: Vec<f32>,
}

/// `out[n × din] = g[n × dout] · wᵀ` where `w` is `[din × dout]`
/// row-major. Row-chunked across the pool; each output row is a series
/// of dot products against rows of `w` (both streams contiguous).
pub(crate) fn matmul_wt_parallel(
    pool: &ThreadPool,
    g: &[f32],
    n: usize,
    dout: usize,
    w: &[f32],
    din: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(g.len(), n * dout);
    debug_assert_eq!(w.len(), din * dout);
    debug_assert_eq!(out.len(), n * din);
    if n == 0 || din == 0 {
        return;
    }
    let chunk = n.div_ceil(pool.size().max(1)).max(1);
    let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = out
        .chunks_mut(chunk * din)
        .enumerate()
        .map(|(ci, ochunk)| {
            let rows = ochunk.len() / din;
            let lo = ci * chunk;
            let gs = &g[lo * dout..(lo + rows) * dout];
            Box::new(move || {
                for r in 0..rows {
                    let grow = &gs[r * dout..(r + 1) * dout];
                    let orow = &mut ochunk[r * din..(r + 1) * din];
                    for (k, o) in orow.iter_mut().enumerate() {
                        let wrow = &w[k * dout..(k + 1) * dout];
                        let mut acc = 0f32;
                        for j in 0..dout {
                            acc += grow[j] * wrow[j];
                        }
                        *o = acc;
                    }
                }
            }) as Box<dyn FnOnce() + Send + '_>
        })
        .collect();
    pool.scoped_run(jobs);
}

/// `(dw, db) = (zᵀ·g, column sums of g)` for `z: [n × din]`,
/// `g: [n × dout]`. Rows are chunked across the pool; each shard
/// accumulates a private `[din × dout]` + `[dout]` buffer, reduced in
/// shard order after the join (deterministic for a fixed thread count).
pub(crate) fn grad_wb_parallel(
    pool: &ThreadPool,
    z: &[f32],
    g: &[f32],
    n: usize,
    din: usize,
    dout: usize,
) -> (Vec<f32>, Vec<f32>) {
    debug_assert_eq!(z.len(), n * din);
    debug_assert_eq!(g.len(), n * dout);
    let n_shards = pool.size().max(1).min(n.max(1));
    let chunk = n.div_ceil(n_shards).max(1);
    let mut partials: Vec<(Vec<f32>, Vec<f32>)> =
        (0..n_shards).map(|_| (vec![0f32; din * dout], vec![0f32; dout])).collect();
    let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = partials
        .iter_mut()
        .enumerate()
        .map(|(ci, (dw, db))| {
            let lo = (ci * chunk).min(n);
            let hi = ((ci + 1) * chunk).min(n);
            let zs = &z[lo * din..hi * din];
            let gs = &g[lo * dout..hi * dout];
            Box::new(move || {
                for r in 0..hi - lo {
                    let grow = &gs[r * dout..(r + 1) * dout];
                    for (j, d) in db.iter_mut().enumerate() {
                        *d += grow[j];
                    }
                    let zrow = &zs[r * din..(r + 1) * din];
                    for (k, &zv) in zrow.iter().enumerate() {
                        if zv == 0.0 {
                            continue;
                        }
                        let drow = &mut dw[k * dout..(k + 1) * dout];
                        for j in 0..dout {
                            drow[j] += zv * grow[j];
                        }
                    }
                }
            }) as Box<dyn FnOnce() + Send + '_>
        })
        .collect();
    pool.scoped_run(jobs);
    // shard-order reduction
    let mut dw = vec![0f32; din * dout];
    let mut db = vec![0f32; dout];
    for (pw, pb) in &partials {
        for (d, s) in dw.iter_mut().zip(pw) {
            *d += *s;
        }
        for (d, s) in db.iter_mut().zip(pb) {
            *d += *s;
        }
    }
    (dw, db)
}

/// ReLU backward in place: `g[i] ← 0` wherever the recorded activation
/// `h[i]` was clamped (`h[i] ≤ 0`).
#[inline]
fn relu_backward(g: &mut [f32], h: &[f32]) {
    debug_assert_eq!(g.len(), h.len());
    for (gv, &hv) in g.iter_mut().zip(h) {
        if hv <= 0.0 {
            *gv = 0.0;
        }
    }
}

/// Full backward pass: from `dlogits` (`dL/d(last affine output)`,
/// `[n × out_dim]`) to every `dW_l`, `db_l` — and `dL/dX` when
/// `want_dx` (the layer-0 transpose SpMM is skipped otherwise, since no
/// parameters sit below it). `plan_t` must be the plan over `Âᵀ`
/// (identical to the forward plan when `Â` is symmetric). Timings
/// accumulate into `phases`.
pub fn backward(
    plan_t: &SpmmPlan,
    pool: &ThreadPool,
    model: &GcnModel,
    tape: &Tape,
    dlogits: &[f32],
    want_dx: bool,
    phases: &mut PhaseBreakdown,
) -> Gradients {
    let n = tape.n;
    let dims = model.dims();
    let n_layers = dims.len();
    assert_eq!(dlogits.len(), n * dims[n_layers - 1].1, "dlogits shape mismatch");
    let mut dw: Vec<Vec<f32>> = vec![Vec::new(); n_layers];
    let mut db: Vec<Vec<f32>> = vec![Vec::new(); n_layers];
    let mut g = dlogits.to_vec();
    let mut dx = Vec::new();
    for l in (0..n_layers).rev() {
        let (din, dout) = dims[l];
        debug_assert_eq!(g.len(), n * dout);
        // dW_l = Z_lᵀ·G, db_l = Σ G
        let t0 = Instant::now();
        let (dwl, dbl) = grad_wb_parallel(pool, &tape.zs[l], &g, n, din, dout);
        dw[l] = dwl;
        db[l] = dbl;
        if l == 0 && !want_dx {
            phases.bwd_dense += t0.elapsed().as_secs_f64();
            break;
        }
        // dZ_l = G · W_lᵀ
        let mut dz = vec![0f32; n * din];
        matmul_wt_parallel(pool, &g, n, dout, &model.weights[l], din, &mut dz);
        phases.bwd_dense += t0.elapsed().as_secs_f64();
        // dH_{l-1} = Âᵀ · dZ_l
        let t1 = Instant::now();
        let mut dh = vec![0f32; n * din];
        spmm_block_level_parallel_into(plan_t, &dz, din, pool, &mut dh);
        phases.bwd_spmm += t1.elapsed().as_secs_f64();
        if l == 0 {
            dx = dh;
        } else {
            // dA_{l-1} = dH_{l-1} ⊙ 1[H_{l-1} > 0]; H_{l-1} is layer
            // l-1's recorded activation
            relu_backward(&mut dh, &tape.acts[l - 1]);
            g = dh;
        }
    }
    Gradients { dw, db, dx }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg;

    fn naive_wt(g: &[f32], n: usize, dout: usize, w: &[f32], din: usize) -> Vec<f32> {
        let mut out = vec![0f32; n * din];
        for r in 0..n {
            for k in 0..din {
                for j in 0..dout {
                    out[r * din + k] += g[r * dout + j] * w[k * dout + j];
                }
            }
        }
        out
    }

    #[test]
    fn matmul_wt_matches_naive_across_threads() {
        let (n, din, dout) = (33, 7, 5);
        let mut rng = Pcg::seed_from(21);
        let g: Vec<f32> = (0..n * dout).map(|_| rng.f32() - 0.5).collect();
        let w: Vec<f32> = (0..din * dout).map(|_| rng.f32() - 0.5).collect();
        let want = naive_wt(&g, n, dout, &w, din);
        for threads in [1usize, 3, 8] {
            let pool = ThreadPool::new(threads);
            let mut out = vec![0f32; n * din];
            matmul_wt_parallel(&pool, &g, n, dout, &w, din, &mut out);
            crate::spmm::verify::assert_allclose(&out, &want, 1e-5, 1e-5, "matmul_wt");
        }
    }

    #[test]
    fn grad_wb_matches_naive_across_threads() {
        let (n, din, dout) = (41, 6, 4);
        let mut rng = Pcg::seed_from(22);
        let z: Vec<f32> = (0..n * din).map(|_| rng.f32() - 0.5).collect();
        let g: Vec<f32> = (0..n * dout).map(|_| rng.f32() - 0.5).collect();
        let mut want_dw = vec![0f32; din * dout];
        let mut want_db = vec![0f32; dout];
        for r in 0..n {
            for j in 0..dout {
                want_db[j] += g[r * dout + j];
                for k in 0..din {
                    want_dw[k * dout + j] += z[r * din + k] * g[r * dout + j];
                }
            }
        }
        for threads in [1usize, 2, 8] {
            let pool = ThreadPool::new(threads);
            let (dw, db) = grad_wb_parallel(&pool, &z, &g, n, din, dout);
            crate::spmm::verify::assert_allclose(&dw, &want_dw, 1e-4, 1e-4, "dw");
            crate::spmm::verify::assert_allclose(&db, &want_db, 1e-4, 1e-4, "db");
        }
    }

    #[test]
    fn grad_wb_deterministic_for_fixed_threads() {
        let (n, din, dout) = (57, 5, 3);
        let mut rng = Pcg::seed_from(23);
        let z: Vec<f32> = (0..n * din).map(|_| rng.f32() - 0.5).collect();
        let g: Vec<f32> = (0..n * dout).map(|_| rng.f32() - 0.5).collect();
        let pool = ThreadPool::new(4);
        let (dw1, db1) = grad_wb_parallel(&pool, &z, &g, n, din, dout);
        let (dw2, db2) = grad_wb_parallel(&pool, &z, &g, n, din, dout);
        assert_eq!(dw1, dw2, "dw must be bit-stable");
        assert_eq!(db1, db2, "db must be bit-stable");
    }

    #[test]
    fn relu_backward_masks() {
        let mut g = vec![1.0f32, 2.0, 3.0, 4.0];
        relu_backward(&mut g, &[0.5, 0.0, -1.0, 2.0]);
        assert_eq!(g, vec![1.0, 0.0, 0.0, 4.0]);
    }

    #[test]
    fn empty_and_tiny_shapes() {
        let pool = ThreadPool::new(2);
        let mut out: Vec<f32> = Vec::new();
        matmul_wt_parallel(&pool, &[], 0, 3, &[0.0; 6], 2, &mut out);
        let (dw, db) = grad_wb_parallel(&pool, &[], &[], 0, 2, 3);
        assert!(dw.iter().all(|&v| v == 0.0) && db.iter().all(|&v| v == 0.0));
        // single row
        let (dw, db) = grad_wb_parallel(&pool, &[2.0, 3.0], &[5.0], 1, 2, 1);
        assert_eq!(dw, vec![10.0, 15.0]);
        assert_eq!(db, vec![5.0]);
    }
}
