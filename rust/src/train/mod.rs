//! Native training subsystem: full-graph GCN training — forward with a
//! tape, masked softmax cross-entropy, exact backprop, and an optimizer
//! step — entirely on the parallel SpMM pipeline. No Python, no PJRT
//! artifacts (the [`bench::train`](crate::bench::train) path needs
//! those; this one works offline).
//!
//! The backward pass needs SpMM against `Âᵀ` (`dH = Âᵀ·G`). The
//! [`Trainer`] obtains that plan through the same
//! [`PlanCache`](crate::pipeline::PlanCache) as the forward plan,
//! fingerprint-keyed — and when the normalized adjacency is symmetric
//! (every undirected GCN graph: `Â = D^{-1/2}(A+I)D^{-1/2}` of a
//! symmetric pattern is symmetric, checked by
//! [`Csr::is_symmetric`](crate::graph::csr::Csr::is_symmetric)) the
//! forward plan is **reused verbatim** — zero extra preprocessing, one
//! cache entry. Both directions execute through the PR-4 tiled
//! microkernel
//! ([`spmm_block_level_parallel_into`](crate::pipeline::spmm_block_level_parallel_into)).
//!
//! Module map:
//! * [`tape`] — forward pass recording per-layer `Z_l`/`H_l` (the dense
//!   affine is shared with [`serve::gcn`](crate::serve::gcn)).
//! * [`backward`] — `dW`/`db`/`dX` through ReLU → affine → SpMM per
//!   layer; dense GEMMs sharded over the
//!   [`ThreadPool`](crate::util::threadpool::ThreadPool) with
//!   deterministic shard-order reductions.
//! * [`loss`] — masked softmax cross-entropy + accuracy.
//! * [`optim`] — SGD(+momentum) and Adam behind the
//!   [`Optimizer`](optim::Optimizer) trait.
//! * [`Trainer`] (here) — drives steps over train/val/test masks with
//!   early stopping on validation loss, producing a [`TrainReport`]
//!   with a per-phase time breakdown (fwd-SpMM / fwd-dense / bwd-SpMM /
//!   bwd-dense / opt).

pub mod backward;
pub mod loss;
pub mod optim;
pub mod tape;

pub use backward::{backward, Gradients};
pub use loss::{masked_accuracy, masked_softmax_xent, masked_softmax_xent_loss};
pub use optim::Optimizer;
pub use tape::{forward_with_tape, Tape};

use crate::graph::csr::Csr;
use crate::graph::datasets::LabeledDataset;
use crate::model::ModelConfig;
use crate::partition::patterns::PartitionParams;
use crate::pipeline::{PlanCache, SpmmPlan};
use crate::serve::gcn::GcnModel;
use crate::util::threadpool::ThreadPool;
use anyhow::{ensure, Result};
use std::sync::Arc;
use std::time::Instant;

/// Wall-clock seconds per training phase, accumulated across steps.
#[derive(Clone, Copy, Debug, Default)]
pub struct PhaseBreakdown {
    pub fwd_spmm: f64,
    pub fwd_dense: f64,
    pub bwd_spmm: f64,
    pub bwd_dense: f64,
    pub opt: f64,
}

impl PhaseBreakdown {
    pub fn total(&self) -> f64 {
        self.fwd_spmm + self.fwd_dense + self.bwd_spmm + self.bwd_dense + self.opt
    }

    /// One-line human summary (µs per step).
    pub fn render_per_step(&self, steps: usize) -> String {
        let per = |s: f64| s / steps.max(1) as f64 * 1e6;
        format!(
            "fwd-spmm {:.0}µs  fwd-dense {:.0}µs  bwd-spmm {:.0}µs  bwd-dense {:.0}µs  opt {:.0}µs",
            per(self.fwd_spmm),
            per(self.fwd_dense),
            per(self.bwd_spmm),
            per(self.bwd_dense),
            per(self.opt),
        )
    }
}

/// Training-run configuration. `model.lr` must be set (> 0) via
/// [`ModelConfig::with_lr`] — the constructor rejects the default 0.0.
#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub model: ModelConfig,
    /// `sgd` or `adam`.
    pub optimizer: String,
    /// SGD momentum (ignored by Adam).
    pub momentum: f64,
    /// Full-graph steps (one forward+backward+update each).
    pub steps: usize,
    /// Stop after this many consecutive steps without a new best
    /// validation loss; 0 disables early stopping.
    pub patience: usize,
    /// A step only counts as an improvement when it beats the best
    /// validation loss by more than this margin (keeps asymptotic
    /// micro-improvements from postponing the stop forever).
    pub min_delta: f64,
    pub threads: usize,
    pub seed: u64,
    /// Print a progress line every `log_every` steps; 0 silences.
    pub log_every: usize,
    /// Run the [`PlanTuner`](crate::tune::PlanTuner) over the trainer's
    /// plans every this many steps (0 = tuning off). Effective only
    /// while the global observability registry is enabled; tuned plans
    /// are bit-identical, so losses never change — only steps/sec.
    pub tune_every: usize,
}

impl Default for TrainConfig {
    fn default() -> TrainConfig {
        TrainConfig {
            model: ModelConfig::gcn(16, 16, 4, 2).with_lr(0.1),
            optimizer: "sgd".to_string(),
            momentum: 0.9,
            steps: 100,
            patience: 0,
            min_delta: 1e-4,
            threads: 4,
            seed: 42,
            log_every: 0,
            tune_every: 0,
        }
    }
}

/// Result of one [`Trainer::train`] run.
#[derive(Clone, Debug)]
pub struct TrainReport {
    /// Train loss per executed step.
    pub losses: Vec<f64>,
    /// Validation loss per executed step.
    pub val_losses: Vec<f64>,
    pub train_accuracy: f64,
    pub val_accuracy: f64,
    pub test_accuracy: f64,
    pub steps_per_sec: f64,
    pub phases: PhaseBreakdown,
    pub stopped_early: bool,
}

impl TrainReport {
    pub fn initial_loss(&self) -> f64 {
        self.losses.first().copied().unwrap_or(f64::NAN)
    }

    pub fn final_loss(&self) -> f64 {
        self.losses.last().copied().unwrap_or(f64::NAN)
    }
}

/// Statistics of one training step.
#[derive(Clone, Copy, Debug)]
pub struct StepStats {
    pub loss: f64,
}

/// The default learning rate per optimizer name — the single source the
/// CLI and the bench both read, so their defaults cannot drift.
pub fn default_lr(optimizer: &str) -> f64 {
    if optimizer == "adam" {
        0.02
    } else {
        0.1
    }
}

/// The native training engine: one normalized adjacency, its forward
/// and transpose plans (shared when symmetric), a thread pool, model
/// parameters, and an optimizer.
pub struct Trainer {
    pub plan: Arc<SpmmPlan>,
    /// Plan over `Âᵀ`; the same `Arc` as `plan` when `Â` is symmetric.
    pub plan_t: Arc<SpmmPlan>,
    /// Whether the symmetric fast path reused the forward plan.
    pub transpose_reused: bool,
    pub model: GcnModel,
    opt: Box<dyn Optimizer>,
    pool: ThreadPool,
    cfg: TrainConfig,
}

impl Trainer {
    /// Build a trainer against the process-global [`PlanCache`].
    /// `adj` should be the **normalized** adjacency (`gcn_normalize`).
    pub fn new(adj: &Csr, cfg: TrainConfig) -> Result<Trainer> {
        Trainer::with_cache(adj, cfg, PlanCache::global())
    }

    /// [`Trainer::new`] with an explicit cache (tests, multi-tenant
    /// embedding). Both the forward plan and — for asymmetric
    /// adjacencies — the transposed plan are built/reused through
    /// `cache`, fingerprint-keyed like every other consumer's plans.
    pub fn with_cache(adj: &Csr, cfg: TrainConfig, cache: &PlanCache) -> Result<Trainer> {
        ensure!(adj.n_rows == adj.n_cols, "training needs a square adjacency");
        ensure!(adj.n_rows >= 1, "empty graph");
        ensure!(
            cfg.model.lr > 0.0,
            "learning rate is unset ({}): call ModelConfig::with_lr",
            cfg.model.lr
        );
        ensure!(cfg.steps > 0, "steps must be ≥ 1");
        let params = PartitionParams::default();
        let plan = cache.plan_for(adj, params);
        // the backward direction: reuse the forward plan when Âᵀ == Â,
        // otherwise cache a transposed plan alongside it (one transpose
        // pass serves both the symmetry check and the plan build)
        let at = adj.transpose();
        let (plan_t, transpose_reused) = if at == *adj {
            (Arc::clone(&plan), true)
        } else {
            (cache.plan_for(&at, params), false)
        };
        let opt = optim::by_name(&cfg.optimizer, cfg.model.lr, cfg.momentum)?;
        let model = GcnModel::random(cfg.model.clone(), cfg.seed);
        let pool = ThreadPool::new(cfg.threads);
        Ok(Trainer { plan, plan_t, transpose_reused, model, opt, pool, cfg })
    }

    /// The model's output dimension (class count).
    fn out_dim(&self) -> usize {
        self.cfg.model.out_dim
    }

    /// One closed-loop tuning pass over the trainer's plans (forward
    /// and, when distinct, transpose): re-cut shard boundaries against
    /// the cost measured in the registry's per-shard timeline. Swapped
    /// plans are bit-identical to the old ones, so training trajectories
    /// are untouched — only steps/sec moves. The symmetric fast path's
    /// invariant (`plan_t` is the same `Arc` as `plan`) is preserved
    /// across swaps.
    fn tune_plans(&mut self) {
        let reg = crate::obs::Registry::global();
        if !reg.enabled() {
            return;
        }
        let tuner = crate::tune::PlanTuner::default();
        let n_shards = self.pool.size();
        let mut swapped = false;
        if let Some(tuned) = tuner.maybe_tune(reg, &self.plan, n_shards) {
            let tuned = Arc::new(tuned);
            if self.transpose_reused {
                self.plan_t = Arc::clone(&tuned);
            }
            self.plan = tuned;
            reg.counter("tune.swaps").inc();
            swapped = true;
        }
        if !self.transpose_reused {
            if let Some(tuned) = tuner.maybe_tune(reg, &self.plan_t, n_shards) {
                self.plan_t = Arc::new(tuned);
                reg.counter("tune.swaps").inc();
                swapped = true;
            }
        }
        if swapped {
            reg.reset_shards();
        }
    }

    /// Forward only: logits in original row order.
    pub fn logits(&self, x: &[f32]) -> Vec<f32> {
        let mut phases = PhaseBreakdown::default();
        forward_with_tape(&self.plan, &self.pool, &self.model, x, &mut phases).into_logits()
    }

    /// Check the backward direction on this trainer's own pool: the
    /// transpose plan's parallel SpMM against the dense `Âᵀ·G`
    /// reference on a seeded random `G` — **bit-for-bit** when the plan
    /// has no split rows (`max_degree ≤ deg_bound`; each output lane
    /// then accumulates the identical f32 sequence), elementwise-close
    /// otherwise. The CLI and the `train_native` bench both gate on
    /// this before training.
    pub fn verify_backward_spmm(&self, f: usize, seed: u64) -> bool {
        let at = &self.plan_t.original;
        let mut rng = crate::util::rng::Pcg::seed_from(seed ^ 0xbacc);
        let g: Vec<f32> = (0..at.n_cols * f).map(|_| rng.f32() - 0.5).collect();
        let got = crate::pipeline::spmm_block_level_parallel(&self.plan_t, &g, f, &self.pool);
        let want = at.spmm_dense(&g, f);
        if at.max_degree() <= self.plan_t.params.deg_bound() {
            got == want
        } else {
            crate::spmm::verify::allclose(&got, &want, 1e-4, 1e-4)
        }
    }

    /// One full-graph step: forward (tape) → masked loss/grad → backward
    /// → optimizer. Returns the pre-update training loss.
    pub fn step(
        &mut self,
        x: &[f32],
        labels: &[u32],
        train_mask: &[bool],
        phases: &mut PhaseBreakdown,
    ) -> Result<StepStats> {
        let (loss, _) = self.step_with_logits(x, labels, train_mask, phases);
        Ok(StepStats { loss })
    }

    /// The one step sequence both [`Trainer::step`] and
    /// [`Trainer::train`] run: forward (tape) → masked train loss/grad →
    /// backward → optimizer. Returns the pre-update loss and the
    /// pre-update logits (so the epoch loop can read validation metrics
    /// from the same forward pass).
    fn step_with_logits(
        &mut self,
        x: &[f32],
        labels: &[u32],
        train_mask: &[bool],
        phases: &mut PhaseBreakdown,
    ) -> (f64, Vec<f32>) {
        // the phase breakdown stays the step's return-value view (tests
        // and the bench table read it); the same per-phase durations are
        // also emitted as spans into the global registry so `profile`
        // and `--metrics-out` see training alongside serve/SpMM data
        let reg = crate::obs::Registry::global();
        let step_span = reg.span("train_step");
        let before = *phases;
        let tape = forward_with_tape(&self.plan, &self.pool, &self.model, x, &mut *phases);
        let (loss, dlogits) =
            masked_softmax_xent(tape.logits(), labels, train_mask, self.out_dim());
        let grads = backward(
            &self.plan_t,
            &self.pool,
            &self.model,
            &tape,
            &dlogits,
            false,
            phases,
        );
        let t0 = Instant::now();
        self.opt.step(&mut self.model, &grads);
        phases.opt += t0.elapsed().as_secs_f64();
        if reg.enabled() {
            for (name, secs) in [
                ("train_step/fwd_spmm", phases.fwd_spmm - before.fwd_spmm),
                ("train_step/fwd_dense", phases.fwd_dense - before.fwd_dense),
                ("train_step/bwd_spmm", phases.bwd_spmm - before.bwd_spmm),
                ("train_step/bwd_dense", phases.bwd_dense - before.bwd_dense),
                ("train_step/opt", phases.opt - before.opt),
            ] {
                reg.record_span_ns(name, (secs * 1e9) as u64);
            }
        }
        drop(step_span);
        (loss, tape.into_logits())
    }

    /// Train on a labeled dataset: `cfg.steps` full-graph steps with
    /// per-step validation loss (computed from the same forward pass —
    /// masks only affect the loss, not the logits) and optional early
    /// stopping on the best validation loss.
    pub fn train(&mut self, data: &LabeledDataset) -> Result<TrainReport> {
        let n = data.n_nodes();
        ensure!(n == self.plan.n_rows(), "dataset/plan size mismatch");
        ensure!(data.feat_dim == self.cfg.model.in_dim, "feature dim != model in_dim");
        ensure!(data.n_classes <= self.out_dim(), "more classes than model outputs");
        let mut phases = PhaseBreakdown::default();
        let mut losses = Vec::with_capacity(self.cfg.steps);
        let mut val_losses = Vec::with_capacity(self.cfg.steps);
        let mut best_val = f64::INFINITY;
        let mut since_best = 0usize;
        let mut stopped_early = false;
        let t0 = Instant::now();
        for step in 0..self.cfg.steps {
            // one shared step; val loss is read from the same pre-update
            // logits the train loss came from (masks only affect loss)
            let (loss, logits) =
                self.step_with_logits(&data.features, &data.labels, &data.train_mask, &mut phases);
            let val_loss =
                loss::masked_softmax_xent_loss(&logits, &data.labels, &data.val_mask, self.out_dim());
            losses.push(loss);
            val_losses.push(val_loss);
            if self.cfg.tune_every > 0 && (step + 1) % self.cfg.tune_every == 0 {
                self.tune_plans();
            }
            if self.cfg.log_every > 0 && (step % self.cfg.log_every == 0 || step + 1 == self.cfg.steps) {
                println!("step {step:>5}  train loss {loss:.4}  val loss {val_loss:.4}");
            }
            if val_loss < best_val - self.cfg.min_delta {
                best_val = val_loss;
                since_best = 0;
            } else {
                since_best += 1;
                if self.cfg.patience > 0 && since_best >= self.cfg.patience {
                    stopped_early = true;
                    if self.cfg.log_every > 0 {
                        println!(
                            "early stop at step {step}: no val improvement in {} steps (best {best_val:.4})",
                            self.cfg.patience
                        );
                    }
                    break;
                }
            }
        }
        let elapsed = t0.elapsed().as_secs_f64();
        // final metrics from one last forward over the updated weights
        let logits = self.logits(&data.features);
        let k = self.out_dim();
        Ok(TrainReport {
            steps_per_sec: losses.len() as f64 / elapsed.max(1e-12),
            train_accuracy: masked_accuracy(&logits, &data.labels, &data.train_mask, k),
            val_accuracy: masked_accuracy(&logits, &data.labels, &data.val_mask, k),
            test_accuracy: masked_accuracy(&logits, &data.labels, &data.test_mask, k),
            losses,
            val_losses,
            phases,
            stopped_early,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::datasets::{labeled_synthetic, labeled_synthetic_with};
    use crate::pipeline::spmm_block_level_parallel;
    use crate::spmm::verify::assert_allclose;
    use crate::util::proptest;
    use crate::util::rng::Pcg;

    fn cfg(model: ModelConfig, optimizer: &str, steps: usize) -> TrainConfig {
        TrainConfig {
            model,
            optimizer: optimizer.to_string(),
            steps,
            threads: 2,
            ..TrainConfig::default()
        }
    }

    /// f64 dense reference of the whole forward + masked loss — the
    /// independent oracle the finite-difference check differentiates.
    struct DenseRef {
        adj: Vec<f64>, // n × n
        n: usize,
        dims: Vec<(usize, usize)>,
        weights: Vec<Vec<f64>>,
        biases: Vec<Vec<f64>>,
        x: Vec<f64>,
        labels: Vec<u32>,
        mask: Vec<bool>,
    }

    impl DenseRef {
        fn of(adj: &Csr, model: &GcnModel, x: &[f32], labels: &[u32], mask: &[bool]) -> DenseRef {
            let n = adj.n_rows;
            let mut dense = vec![0f64; n * n];
            for r in 0..n {
                for (c, v) in adj.row(r) {
                    dense[r * n + c as usize] = v as f64;
                }
            }
            DenseRef {
                adj: dense,
                n,
                dims: model.dims(),
                weights: model.weights.iter().map(|w| w.iter().map(|&v| v as f64).collect()).collect(),
                biases: model.biases.iter().map(|b| b.iter().map(|&v| v as f64).collect()).collect(),
                x: x.iter().map(|&v| v as f64).collect(),
                labels: labels.to_vec(),
                mask: mask.to_vec(),
            }
        }

        fn loss(&self) -> f64 {
            let n = self.n;
            let mut h = self.x.clone();
            for (l, &(din, dout)) in self.dims.iter().enumerate() {
                // z = A·h
                let mut z = vec![0f64; n * din];
                for r in 0..n {
                    for c in 0..n {
                        let a = self.adj[r * n + c];
                        if a != 0.0 {
                            for k in 0..din {
                                z[r * din + k] += a * h[c * din + k];
                            }
                        }
                    }
                }
                // a = z·W + b (+ ReLU on hidden layers)
                let relu = l + 1 < self.dims.len();
                let mut out = vec![0f64; n * dout];
                for r in 0..n {
                    for j in 0..dout {
                        let mut acc = self.biases[l][j];
                        for k in 0..din {
                            acc += z[r * din + k] * self.weights[l][k * dout + j];
                        }
                        out[r * dout + j] = if relu { acc.max(0.0) } else { acc };
                    }
                }
                h = out;
            }
            // masked mean softmax cross-entropy
            let k = self.dims.last().unwrap().1;
            let m = self.mask.iter().filter(|&&b| b).count();
            let mut loss = 0f64;
            for i in 0..n {
                if !self.mask[i] {
                    continue;
                }
                let row = &h[i * k..(i + 1) * k];
                let max = row.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
                let lse = max + row.iter().map(|&z| (z - max).exp()).sum::<f64>().ln();
                loss += lse - row[self.labels[i] as usize];
            }
            loss / m as f64
        }

        /// Central difference of the loss wrt one scalar reached by
        /// `access`.
        fn central_diff(&mut self, eps: f64, access: impl Fn(&mut DenseRef) -> &mut f64) -> f64 {
            let orig = *access(self);
            *access(self) = orig + eps;
            let up = self.loss();
            *access(self) = orig - eps;
            let down = self.loss();
            *access(self) = orig;
            (up - down) / (2.0 * eps)
        }
    }

    fn assert_grad_close(analytic: f32, numeric: f64, what: &str) {
        let (a, n) = (analytic as f64, numeric);
        let tol = 1e-2 * a.abs().max(n.abs()) + 1e-4;
        assert!(
            (a - n).abs() <= tol,
            "{what}: analytic {a:.6e} vs central-diff {n:.6e} (|Δ|={:.2e} > tol {tol:.2e})",
            (a - n).abs()
        );
    }

    /// The finite-difference satellite: analytic dW, db, dX vs central
    /// differences of the f64 dense oracle, across the paper-relevant
    /// ragged/full feature widths.
    #[test]
    fn prop_gradients_match_finite_differences() {
        for &f in &[3usize, 16, 17] {
            proptest::check(&format!("grad_check_f{f}"), 0x96AD ^ f as u64, 3, |rng| {
                let n = rng.range(6, 14);
                let classes = 3;
                let hidden = rng.range(3, 6);
                // random graph, normalized like a real training run
                let mut edges = vec![(0u32, 0u32, 1.0f32)];
                for r in 0..n {
                    for _ in 0..rng.range(1, 5) {
                        edges.push((r as u32, rng.range(0, n) as u32, 1.0));
                    }
                }
                let adj = Csr::from_edges(n, n, &edges).unwrap().gcn_normalize();
                let model_cfg = ModelConfig::gcn(f, hidden, classes, 2).with_lr(0.1);
                let model = GcnModel::random(model_cfg, rng.next_u64());
                let x: Vec<f32> = (0..n * f).map(|_| rng.f32() - 0.5).collect();
                let labels: Vec<u32> = (0..n).map(|_| rng.range(0, classes) as u32).collect();
                let mask: Vec<bool> = (0..n).map(|i| i % 2 == 0 || i + 1 == n).collect();

                // analytic gradients through the parallel pipeline
                let plan = SpmmPlan::build(adj.clone(), PartitionParams::default());
                let plan_t = SpmmPlan::build(adj.transpose(), PartitionParams::default());
                let pool = ThreadPool::new(2);
                let mut phases = PhaseBreakdown::default();
                let tape = forward_with_tape(&plan, &pool, &model, &x, &mut phases);
                let (_, dlogits) = masked_softmax_xent(tape.logits(), &labels, &mask, classes);
                let grads =
                    backward(&plan_t, &pool, &model, &tape, &dlogits, true, &mut phases);

                let mut oracle = DenseRef::of(&adj, &model, &x, &labels, &mask);
                let eps = 1e-4;
                // all weight/bias coordinates (layers are tiny)
                for l in 0..2 {
                    for i in 0..grads.dw[l].len() {
                        let nd = oracle.central_diff(eps, |o| &mut o.weights[l][i]);
                        assert_grad_close(grads.dw[l][i], nd, &format!("dW[{l}][{i}] f={f}"));
                    }
                    for i in 0..grads.db[l].len() {
                        let nd = oracle.central_diff(eps, |o| &mut o.biases[l][i]);
                        assert_grad_close(grads.db[l][i], nd, &format!("db[{l}][{i}] f={f}"));
                    }
                }
                // a sample of dX coordinates
                assert_eq!(grads.dx.len(), n * f);
                for _ in 0..12 {
                    let i = rng.range(0, n * f);
                    let nd = oracle.central_diff(eps, |o| &mut o.x[i]);
                    assert_grad_close(grads.dx[i], nd, &format!("dX[{i}] f={f}"));
                }
            });
        }
    }

    /// The transpose-SpMM satellite: on plans with no split rows, the
    /// parallel executor over `Âᵀ` is **bit-for-bit** the dense `Âᵀ·G`
    /// reference, at every thread count (each output lane accumulates
    /// the identical f32 sequence).
    #[test]
    fn transpose_plan_spmm_bit_for_bit_vs_dense() {
        let mut rng = Pcg::seed_from(0x7A05);
        let n = 60;
        let mut edges = vec![(0u32, 0u32, 1.0f32)];
        for r in 0..n {
            for _ in 0..rng.range(0, 9) {
                edges.push((r as u32, rng.range(0, n) as u32, rng.f32() - 0.5));
            }
        }
        let a = Csr::from_edges(n, n, &edges).unwrap();
        let at = a.transpose();
        let params = PartitionParams::default();
        assert!(
            at.max_degree() <= params.deg_bound(),
            "test premise: no split rows (max deg {} ≤ bound {})",
            at.max_degree(),
            params.deg_bound()
        );
        let plan_t = SpmmPlan::build(at.clone(), params);
        for &f in &[3usize, 16, 17] {
            let g: Vec<f32> = (0..n * f).map(|_| rng.f32() - 0.5).collect();
            let want = at.spmm_dense(&g, f);
            for threads in [1usize, 2, 8] {
                let pool = ThreadPool::new(threads);
                let got = spmm_block_level_parallel(&plan_t, &g, f, &pool);
                assert_eq!(got, want, "f={f} threads={threads}: transpose SpMM must be bit-exact");
            }
        }
    }

    /// Split rows (degree > deg_bound) reduce through per-shard
    /// partials, so bit-equality is not guaranteed — allclose is.
    #[test]
    fn transpose_plan_spmm_allclose_with_split_rows() {
        let params = PartitionParams { max_block_warps: 2, max_warp_nzs: 2 };
        let n = 40;
        let mut rng = Pcg::seed_from(0x7A06);
        let mut edges = Vec::new();
        for c in 0..n {
            // column 0 of A = row 0 of Aᵀ gets degree n (splits)
            edges.push((c as u32, 0u32, rng.f32() - 0.5));
            edges.push((c as u32, rng.range(0, n) as u32, rng.f32() - 0.5));
        }
        let a = Csr::from_edges(n, n, &edges).unwrap();
        let at = a.transpose();
        assert!(at.max_degree() > params.deg_bound(), "test premise: split rows exist");
        let plan_t = SpmmPlan::build(at.clone(), params);
        let f = 5;
        let g: Vec<f32> = (0..n * f).map(|_| rng.f32() - 0.5).collect();
        let want = at.spmm_dense(&g, f);
        for threads in [1usize, 3, 8] {
            let pool = ThreadPool::new(threads);
            let got = spmm_block_level_parallel(&plan_t, &g, f, &pool);
            assert_allclose(&got, &want, 1e-4, 1e-4, "split transpose spmm");
        }
    }

    /// The symmetric fast path: a normalized undirected graph reuses the
    /// forward plan for the backward SpMM — one cache entry, same Arc.
    #[test]
    fn symmetric_adjacency_reuses_forward_plan() {
        let data = labeled_synthetic(80, 3, 0.8, 5);
        let adj = data.csr.gcn_normalize();
        assert!(adj.is_symmetric(), "normalized undirected graph must be symmetric");
        let cache = PlanCache::new();
        let t = Trainer::with_cache(
            &adj,
            cfg(ModelConfig::gcn(data.feat_dim, 8, 3, 2).with_lr(0.1), "sgd", 5),
            &cache,
        )
        .unwrap();
        assert!(t.transpose_reused);
        assert!(Arc::ptr_eq(&t.plan, &t.plan_t), "must share one plan");
        assert_eq!(cache.len(), 1, "no transposed plan cached");
    }

    #[test]
    fn asymmetric_adjacency_caches_transposed_plan() {
        let adj = Csr::from_edges(
            6,
            6,
            &[(0, 1, 0.5), (1, 2, 0.25), (2, 0, 1.0), (3, 3, 1.0), (4, 5, 0.125), (5, 4, 0.5)],
        )
        .unwrap();
        assert!(!adj.is_symmetric());
        let cache = PlanCache::new();
        let t = Trainer::with_cache(
            &adj,
            cfg(ModelConfig::gcn(4, 3, 2, 2).with_lr(0.1), "sgd", 5),
            &cache,
        )
        .unwrap();
        assert!(!t.transpose_reused);
        assert!(!Arc::ptr_eq(&t.plan, &t.plan_t));
        assert_eq!(cache.len(), 2, "forward + transposed plan both cached");
        assert_eq!(t.plan_t.original, adj.transpose());
    }

    #[test]
    fn unset_lr_rejected() {
        let data = labeled_synthetic(40, 2, 0.8, 1);
        let adj = data.csr.gcn_normalize();
        let bad = cfg(ModelConfig::gcn(data.feat_dim, 4, 2, 2), "sgd", 5); // lr left at 0.0
        assert!(Trainer::with_cache(&adj, bad, &PlanCache::new()).is_err());
    }

    /// The acceptance criterion: ≥ 50% loss reduction in 50 steps on the
    /// synthetic labeled graph, with BOTH optimizers.
    #[test]
    fn fifty_steps_halve_the_loss_with_sgd_and_adam() {
        let data = labeled_synthetic_with(200, 4, 16, 6.0, 0.85, 7);
        let adj = data.csr.gcn_normalize();
        for (opt, lr) in [("sgd", 0.1), ("adam", 0.02)] {
            let mut trainer = Trainer::with_cache(
                &adj,
                cfg(ModelConfig::gcn(16, 16, 4, 2).with_lr(lr), opt, 50),
                &PlanCache::new(),
            )
            .unwrap();
            let report = trainer.train(&data).unwrap();
            assert_eq!(report.losses.len(), 50);
            assert!(
                report.final_loss() <= 0.5 * report.initial_loss(),
                "{opt}: loss {:.4} -> {:.4} (needs ≥ 50% drop)",
                report.initial_loss(),
                report.final_loss()
            );
            assert!(
                report.train_accuracy > 1.0 / 4.0,
                "{opt}: train accuracy {:.2} no better than chance",
                report.train_accuracy
            );
            assert!(report.steps_per_sec > 0.0);
            assert!(report.phases.total() > 0.0);
        }
    }

    #[test]
    fn early_stopping_halts_on_plateau() {
        let data = labeled_synthetic(100, 3, 0.85, 11);
        let adj = data.csr.gcn_normalize();
        let mut c = cfg(ModelConfig::gcn(data.feat_dim, 8, 3, 2).with_lr(0.05), "sgd", 400);
        c.patience = 10;
        c.min_delta = 1e-3;
        let mut trainer = Trainer::with_cache(&adj, c, &PlanCache::new()).unwrap();
        let report = trainer.train(&data).unwrap();
        // a 400-step budget on a 100-node toy problem must plateau and
        // stop early well before exhausting the budget
        assert!(report.stopped_early, "expected early stop; ran {} steps", report.losses.len());
        assert!(report.losses.len() < 400);
        assert_eq!(report.losses.len(), report.val_losses.len());
    }

    /// The tuner's contract inside training: re-cutting shards between
    /// steps must not move the loss trajectory by a single bit —
    /// identical seeds with tuning on vs off produce *exactly* equal
    /// losses. (Whether a given window's fit applies is timing-
    /// dependent; bit-identity holds either way, which is exactly what
    /// makes this assertion robust.)
    #[test]
    fn tuning_between_steps_keeps_losses_bit_identical() {
        let reg = crate::obs::Registry::global();
        reg.set_enabled(true);
        let data = labeled_synthetic_with(120, 3, 12, 6.0, 0.85, 13);
        let adj = data.csr.gcn_normalize();
        let run = |tune_every: usize| {
            let mut c = cfg(ModelConfig::gcn(12, 8, 3, 2).with_lr(0.1), "sgd", 12);
            c.tune_every = tune_every;
            let mut trainer = Trainer::with_cache(&adj, c, &PlanCache::new()).unwrap();
            let report = trainer.train(&data).unwrap();
            let shared = Arc::ptr_eq(&trainer.plan, &trainer.plan_t);
            assert_eq!(
                trainer.transpose_reused, shared,
                "tuning must preserve the symmetric single-plan invariant"
            );
            report.losses
        };
        let untuned = run(0);
        let tuned = run(1);
        assert_eq!(untuned.len(), tuned.len());
        for (i, (a, b)) in untuned.iter().zip(&tuned).enumerate() {
            assert!(
                a.to_bits() == b.to_bits(),
                "step {i}: tuned loss {b} != untuned loss {a} (bitwise)"
            );
        }
    }

    #[test]
    fn step_api_reduces_loss() {
        let data = labeled_synthetic(60, 2, 0.9, 3);
        let adj = data.csr.gcn_normalize();
        let mut trainer = Trainer::with_cache(
            &adj,
            cfg(ModelConfig::gcn(data.feat_dim, 8, 2, 2).with_lr(0.1), "sgd", 30),
            &PlanCache::new(),
        )
        .unwrap();
        let mut phases = PhaseBreakdown::default();
        let first = trainer
            .step(&data.features, &data.labels, &data.train_mask, &mut phases)
            .unwrap()
            .loss;
        let mut last = first;
        for _ in 0..29 {
            last = trainer
                .step(&data.features, &data.labels, &data.train_mask, &mut phases)
                .unwrap()
                .loss;
        }
        assert!(last < first, "loss must decrease: {first:.4} -> {last:.4}");
        assert!(phases.fwd_spmm > 0.0 && phases.bwd_dense > 0.0 && phases.opt >= 0.0);
    }
}
