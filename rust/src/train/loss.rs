//! Masked softmax cross-entropy — the training objective and its exact
//! gradient, plus argmax accuracy.
//!
//! Loss and gradient come out of one pass over the logits: per masked
//! row, a numerically-stable log-sum-exp (max-subtracted) gives
//! `loss_i = lse(z_i) - z_i[label_i]`, and the gradient of the *mean*
//! masked loss is `(softmax(z_i) - onehot(label_i)) / m` on masked rows
//! and exactly zero elsewhere — the zero rows are what lets the
//! backward pass run over the full node set without a gather. The loss
//! sum accumulates in f64 so the finite-difference tests compare
//! against a stable scalar.

/// One row's stable cross-entropy pieces:
/// `(lse - z[label], row max, Σ exp(z - max))` — the loss term plus
/// what the gradient variant needs to form softmax probabilities. The
/// single source of the numerical convention for both loss functions.
#[inline]
fn row_xent(row: &[f32], label: usize, k: usize) -> (f64, f32, f64) {
    assert!(label < k, "label {label} out of range for {k} classes");
    let max = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let mut sum = 0f64;
    for &z in row {
        sum += ((z - max) as f64).exp();
    }
    (max as f64 + sum.ln() - row[label] as f64, max, sum)
}

/// Mean softmax cross-entropy over the masked rows of `logits`
/// (`[n × k]` row-major), plus `dL/dlogits` (same shape; zero on
/// unmasked rows). Panics if no row is masked.
pub fn masked_softmax_xent(
    logits: &[f32],
    labels: &[u32],
    mask: &[bool],
    k: usize,
) -> (f64, Vec<f32>) {
    let n = labels.len();
    assert_eq!(logits.len(), n * k, "logit shape mismatch");
    assert_eq!(mask.len(), n, "mask length mismatch");
    let m = mask.iter().filter(|&&b| b).count();
    assert!(m > 0, "empty mask: nothing to train on");
    let inv_m = 1.0 / m as f32;
    let mut grad = vec![0f32; n * k];
    let mut loss = 0f64;
    for i in 0..n {
        if !mask[i] {
            continue;
        }
        let row = &logits[i * k..(i + 1) * k];
        let label = labels[i] as usize;
        let (li, max, sum) = row_xent(row, label, k);
        loss += li;
        let grow = &mut grad[i * k..(i + 1) * k];
        for (j, &z) in row.iter().enumerate() {
            let p = (((z - max) as f64).exp() / sum) as f32;
            grow[j] = (p - (j == label) as u8 as f32) * inv_m;
        }
    }
    (loss / m as f64, grad)
}

/// Loss-only variant of [`masked_softmax_xent`] — no gradient buffer —
/// for evaluation passes (per-step validation loss reads the same
/// logits the training loss already produced).
pub fn masked_softmax_xent_loss(logits: &[f32], labels: &[u32], mask: &[bool], k: usize) -> f64 {
    let n = labels.len();
    assert_eq!(logits.len(), n * k, "logit shape mismatch");
    assert_eq!(mask.len(), n, "mask length mismatch");
    let mut m = 0usize;
    let mut loss = 0f64;
    for i in 0..n {
        if !mask[i] {
            continue;
        }
        let row = &logits[i * k..(i + 1) * k];
        let (li, _, _) = row_xent(row, labels[i] as usize, k);
        loss += li;
        m += 1;
    }
    assert!(m > 0, "empty mask: nothing to evaluate");
    loss / m as f64
}

/// Argmax accuracy over the masked rows (ties resolve to the lowest
/// class id, matching every argmax in this tree). Returns 0.0 on an
/// empty mask.
pub fn masked_accuracy(logits: &[f32], labels: &[u32], mask: &[bool], k: usize) -> f64 {
    let n = labels.len();
    assert_eq!(logits.len(), n * k, "logit shape mismatch");
    let (mut correct, mut total) = (0usize, 0usize);
    for i in 0..n {
        if !mask[i] {
            continue;
        }
        let row = &logits[i * k..(i + 1) * k];
        let mut best = 0usize;
        for j in 1..k {
            if row[j] > row[best] {
                best = j;
            }
        }
        total += 1;
        correct += usize::from(best as u32 == labels[i]);
    }
    if total == 0 {
        0.0
    } else {
        correct as f64 / total as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_logits_give_ln_k() {
        let k = 4;
        let logits = vec![0f32; 3 * k];
        let labels = vec![0u32, 1, 3];
        let mask = vec![true; 3];
        let (loss, grad) = masked_softmax_xent(&logits, &labels, &mask, k);
        assert!((loss - (k as f64).ln()).abs() < 1e-6, "loss {loss}");
        // gradient: (1/k - onehot)/m
        for i in 0..3 {
            for j in 0..k {
                let want = (0.25 - (j as u32 == labels[i]) as u8 as f32) / 3.0;
                assert!((grad[i * k + j] - want).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn gradient_rows_sum_to_zero_and_mask_zeroes() {
        let k = 3;
        let logits = vec![1.0f32, -2.0, 0.5, 3.0, 3.0, -1.0];
        let labels = vec![2u32, 0];
        let mask = vec![true, false];
        let (_, grad) = masked_softmax_xent(&logits, &labels, &mask, k);
        let s: f32 = grad[0..k].iter().sum();
        assert!(s.abs() < 1e-6, "softmax - onehot must sum to 0, got {s}");
        assert!(grad[k..].iter().all(|&g| g == 0.0), "unmasked row must have zero grad");
    }

    #[test]
    fn loss_only_variant_agrees_with_grad_variant() {
        let k = 3;
        let logits = vec![1.0f32, -2.0, 0.5, 3.0, 0.25, -1.0, 0.0, 0.0, 2.0];
        let labels = vec![2u32, 0, 1];
        let mask = vec![true, false, true];
        let (want, _) = masked_softmax_xent(&logits, &labels, &mask, k);
        let got = masked_softmax_xent_loss(&logits, &labels, &mask, k);
        assert!((got - want).abs() < 1e-12, "{got} vs {want}");
    }

    #[test]
    fn confident_correct_prediction_has_low_loss() {
        let logits = vec![10.0f32, -10.0];
        let (lo, _) = masked_softmax_xent(&logits, &[0], &[true], 2);
        let (hi, _) = masked_softmax_xent(&logits, &[1], &[true], 2);
        assert!(lo < 1e-6, "correct confident loss {lo}");
        assert!(hi > 10.0, "wrong confident loss {hi}");
    }

    #[test]
    fn large_logits_stay_finite() {
        let logits = vec![1000.0f32, 999.0, -1000.0];
        let (loss, grad) = masked_softmax_xent(&logits, &[1], &[true], 3);
        assert!(loss.is_finite());
        assert!(grad.iter().all(|g| g.is_finite()));
    }

    #[test]
    fn accuracy_counts_masked_rows_only() {
        let k = 2;
        let logits = vec![2.0f32, 1.0, 0.0, 5.0, 9.0, 1.0];
        let labels = vec![0u32, 1, 1];
        assert_eq!(masked_accuracy(&logits, &labels, &[true, true, true], k), 2.0 / 3.0);
        assert_eq!(masked_accuracy(&logits, &labels, &[true, true, false], k), 1.0);
        assert_eq!(masked_accuracy(&logits, &labels, &[false, false, false], k), 0.0);
    }

    #[test]
    #[should_panic(expected = "empty mask")]
    fn empty_mask_rejected() {
        let _ = masked_softmax_xent(&[0.0, 0.0], &[0], &[false], 2);
    }
}
