//! First-order optimizers over [`GcnModel`] parameters.
//!
//! Both optimizers keep per-parameter state vectors shaped like the
//! model (allocated lazily on the first step so construction needs no
//! dimensions) and update weights and biases in place. Steps are
//! deterministic: same gradients in, same parameters out.

use crate::serve::gcn::GcnModel;
use crate::train::backward::Gradients;
use anyhow::{bail, ensure, Result};

/// One parameter update from one gradient evaluation.
pub trait Optimizer: Send {
    fn name(&self) -> &'static str;
    /// Apply `grads` to `model` in place.
    fn step(&mut self, model: &mut GcnModel, grads: &Gradients);
}

/// Classic SGD with (optional) heavy-ball momentum:
/// `v ← μ·v + g; θ ← θ - lr·v`.
pub struct Sgd {
    lr: f32,
    momentum: f32,
    vel_w: Vec<Vec<f32>>,
    vel_b: Vec<Vec<f32>>,
}

impl Sgd {
    pub fn new(lr: f64, momentum: f64) -> Sgd {
        assert!(lr > 0.0, "lr must be positive");
        assert!((0.0..1.0).contains(&momentum), "momentum must be in [0,1)");
        Sgd { lr: lr as f32, momentum: momentum as f32, vel_w: Vec::new(), vel_b: Vec::new() }
    }
}

fn ensure_like(state: &mut Vec<Vec<f32>>, like: &[Vec<f32>]) {
    if state.len() != like.len() {
        *state = like.iter().map(|g| vec![0f32; g.len()]).collect();
    }
}

impl Optimizer for Sgd {
    fn name(&self) -> &'static str {
        "sgd"
    }

    fn step(&mut self, model: &mut GcnModel, grads: &Gradients) {
        ensure_like(&mut self.vel_w, &grads.dw);
        ensure_like(&mut self.vel_b, &grads.db);
        for l in 0..grads.dw.len() {
            for ((w, g), v) in model.weights[l]
                .iter_mut()
                .zip(&grads.dw[l])
                .zip(self.vel_w[l].iter_mut())
            {
                *v = self.momentum * *v + g;
                *w -= self.lr * *v;
            }
            for ((b, g), v) in
                model.biases[l].iter_mut().zip(&grads.db[l]).zip(self.vel_b[l].iter_mut())
            {
                *v = self.momentum * *v + g;
                *b -= self.lr * *v;
            }
        }
    }
}

/// Adam (Kingma & Ba) with bias correction.
pub struct Adam {
    lr: f32,
    beta1: f32,
    beta2: f32,
    eps: f32,
    t: u32,
    m_w: Vec<Vec<f32>>,
    v_w: Vec<Vec<f32>>,
    m_b: Vec<Vec<f32>>,
    v_b: Vec<Vec<f32>>,
}

impl Adam {
    pub fn new(lr: f64) -> Adam {
        assert!(lr > 0.0, "lr must be positive");
        Adam {
            lr: lr as f32,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            t: 0,
            m_w: Vec::new(),
            v_w: Vec::new(),
            m_b: Vec::new(),
            v_b: Vec::new(),
        }
    }

    #[inline]
    fn update(lr: f32, b1: f32, b2: f32, eps: f32, bc1: f32, bc2: f32, p: &mut f32, g: f32, m: &mut f32, v: &mut f32) {
        *m = b1 * *m + (1.0 - b1) * g;
        *v = b2 * *v + (1.0 - b2) * g * g;
        let mhat = *m / bc1;
        let vhat = *v / bc2;
        *p -= lr * mhat / (vhat.sqrt() + eps);
    }
}

impl Optimizer for Adam {
    fn name(&self) -> &'static str {
        "adam"
    }

    fn step(&mut self, model: &mut GcnModel, grads: &Gradients) {
        ensure_like(&mut self.m_w, &grads.dw);
        ensure_like(&mut self.v_w, &grads.dw);
        ensure_like(&mut self.m_b, &grads.db);
        ensure_like(&mut self.v_b, &grads.db);
        self.t += 1;
        let bc1 = 1.0 - self.beta1.powi(self.t as i32);
        let bc2 = 1.0 - self.beta2.powi(self.t as i32);
        for l in 0..grads.dw.len() {
            for (i, w) in model.weights[l].iter_mut().enumerate() {
                Self::update(
                    self.lr, self.beta1, self.beta2, self.eps, bc1, bc2,
                    w, grads.dw[l][i], &mut self.m_w[l][i], &mut self.v_w[l][i],
                );
            }
            for (i, b) in model.biases[l].iter_mut().enumerate() {
                Self::update(
                    self.lr, self.beta1, self.beta2, self.eps, bc1, bc2,
                    b, grads.db[l][i], &mut self.m_b[l][i], &mut self.v_b[l][i],
                );
            }
        }
    }
}

/// Construct an optimizer by CLI name (`sgd` | `adam`). Validates the
/// hyperparameters here (clean `Result` errors) so the CLI never hits
/// the constructors' programmer-error asserts.
pub fn by_name(name: &str, lr: f64, momentum: f64) -> Result<Box<dyn Optimizer>> {
    ensure!(lr.is_finite() && lr > 0.0, "learning rate must be positive, got {lr}");
    ensure!(
        (0.0..1.0).contains(&momentum),
        "momentum must be in [0, 1), got {momentum}"
    );
    match name {
        "sgd" => Ok(Box::new(Sgd::new(lr, momentum))),
        "adam" => Ok(Box::new(Adam::new(lr))),
        other => bail!("unknown optimizer `{other}` (expected sgd|adam)"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModelConfig;

    /// Drive an optimizer on the 1-d quadratic `f(w) = (w - c)²` whose
    /// gradient is `2(w - c)`, using a 1×1×1 model as the parameter
    /// container.
    fn descend(opt: &mut dyn Optimizer, steps: usize, target: f32) -> f32 {
        let mut model = GcnModel::random(ModelConfig::gcn(1, 1, 1, 1), 3);
        model.weights[0][0] = 0.0;
        model.biases[0][0] = 0.0;
        for _ in 0..steps {
            let w = model.weights[0][0];
            let grads = Gradients {
                dw: vec![vec![2.0 * (w - target)]],
                db: vec![vec![0.0]],
                dx: Vec::new(),
            };
            opt.step(&mut model, &grads);
        }
        model.weights[0][0]
    }

    #[test]
    fn sgd_converges_on_quadratic() {
        let w = descend(&mut Sgd::new(0.1, 0.0), 100, 3.0);
        assert!((w - 3.0).abs() < 1e-3, "plain SGD got {w}");
        let w = descend(&mut Sgd::new(0.05, 0.9), 200, -2.0);
        assert!((w + 2.0).abs() < 1e-2, "momentum SGD got {w}");
    }

    #[test]
    fn adam_converges_on_quadratic() {
        // Adam's sign-like steps settle into an O(lr) neighbourhood of
        // the optimum on a deterministic quadratic (it does not decay to
        // machine precision like SGD); assert the neighbourhood.
        let lr = 0.05;
        let w = descend(&mut Adam::new(lr), 300, 3.0);
        assert!((w - 3.0).abs() < 2.0 * lr as f32, "Adam got {w}");
    }

    #[test]
    fn adam_first_step_is_lr_sized() {
        // bias correction makes the first step ≈ lr · sign(g)
        let mut model = GcnModel::random(ModelConfig::gcn(1, 1, 1, 1), 3);
        model.weights[0][0] = 0.0;
        let mut opt = Adam::new(0.01);
        let grads =
            Gradients { dw: vec![vec![5.0]], db: vec![vec![0.0]], dx: Vec::new() };
        opt.step(&mut model, &grads);
        assert!((model.weights[0][0] + 0.01).abs() < 1e-4, "got {}", model.weights[0][0]);
    }

    #[test]
    fn by_name_resolves_and_validates() {
        assert_eq!(by_name("sgd", 0.1, 0.9).unwrap().name(), "sgd");
        assert_eq!(by_name("adam", 0.1, 0.0).unwrap().name(), "adam");
        assert!(by_name("lbfgs", 0.1, 0.0).is_err());
        // bad hyperparameters are clean errors, not panics
        assert!(by_name("sgd", 0.1, 1.0).is_err());
        assert!(by_name("sgd", 0.1, -0.1).is_err());
        assert!(by_name("sgd", 0.0, 0.9).is_err());
        assert!(by_name("adam", -1.0, 0.0).is_err());
    }
}
