//! Forward pass with a tape: every intermediate the backward pass needs,
//! recorded as it is produced.
//!
//! Layer `l` of the stack computes, in the **original** row domain:
//!
//! ```text
//! Z_l = Â · H_{l-1}          (SpMM through the block-level plan)
//! H_l = act(Z_l · W_l + b_l)  (fused parallel affine; act = ReLU for
//!                              hidden layers, identity for the last)
//! ```
//!
//! The tape stores every `(Z_l, H_l)` pair: `Z_l` is the affine's input
//! (needed for `dW_l = Z_lᵀ·G`), and `H_l > 0` *is* the ReLU mask
//! (exact, since `H_l = max(A_l, 0)` and the gradient at 0 is taken as
//! 0). The input features are **not** copied onto the tape — layer 0
//! reads `x` directly and the backward pass never needs it. The dense
//! affine is the serving path's
//! [`affine_fused_parallel`](crate::serve::gcn) with `k = 1` — training
//! and serving share one dense kernel, as they share one SpMM.
//!
//! Unlike the serve forward (two ping-pong buffers for the whole
//! stack), a tape inherently *keeps* every per-layer buffer alive for
//! the backward pass, so each step allocates its `Z_l`/`H_l` fresh.
//! Revisit with a step-persistent arena if training ever becomes a
//! serving-scale hot path; at bench scale the SpMM/GEMM work dominates.

use crate::pipeline::{spmm_block_level_parallel_into, SpmmPlan};
use crate::serve::gcn::{affine_fused_parallel, GcnModel};
use crate::train::PhaseBreakdown;
use crate::util::threadpool::ThreadPool;
use std::time::Instant;

/// Recorded intermediates of one forward pass over `n` nodes.
pub struct Tape {
    /// `acts[l]` is layer `l`'s output `H_{l+1}` (post-ReLU for hidden
    /// layers); `acts.last()` is the logits. The input `X` is not
    /// stored (backward never reads it).
    pub acts: Vec<Vec<f32>>,
    /// `zs[l] = Â · (layer l's input)` — the SpMM output feeding layer
    /// `l`'s affine.
    pub zs: Vec<Vec<f32>>,
    pub n: usize,
}

impl Tape {
    /// The final layer's output (`[n × out_dim]`, original row order).
    pub fn logits(&self) -> &[f32] {
        self.acts.last().expect("tape has at least one layer")
    }

    /// Consume the tape, returning the logits buffer.
    pub fn into_logits(self) -> Vec<f32> {
        self.acts.into_iter().last().expect("tape has at least one layer")
    }
}

/// Run the stack forward over `x` (`[n × in_dim]`, original row order),
/// recording the tape. Phase timings (SpMM vs dense) accumulate into
/// `phases`.
pub fn forward_with_tape(
    plan: &SpmmPlan,
    pool: &ThreadPool,
    model: &GcnModel,
    x: &[f32],
    phases: &mut PhaseBreakdown,
) -> Tape {
    let n = plan.n_rows();
    let dims = model.dims();
    assert!(!dims.is_empty(), "model has no layers");
    assert_eq!(x.len(), n * dims[0].0, "feature shape mismatch");
    let mut acts: Vec<Vec<f32>> = Vec::with_capacity(dims.len());
    let mut zs: Vec<Vec<f32>> = Vec::with_capacity(dims.len());
    for (l, &(din, dout)) in dims.iter().enumerate() {
        // layer 0 borrows the caller's features directly — no tape copy
        let input: &[f32] = if l == 0 { x } else { &acts[l - 1] };
        debug_assert_eq!(input.len(), n * din);
        let mut z = vec![0f32; n * din];
        let t0 = Instant::now();
        spmm_block_level_parallel_into(plan, input, din, pool, &mut z);
        phases.fwd_spmm += t0.elapsed().as_secs_f64();
        let relu = l + 1 < dims.len();
        let mut a = vec![0f32; n * dout];
        let t1 = Instant::now();
        affine_fused_parallel(
            pool,
            &z,
            n,
            1,
            din,
            &model.weights[l],
            dout,
            &model.biases[l],
            relu,
            &mut a,
        );
        phases.fwd_dense += t1.elapsed().as_secs_f64();
        zs.push(z);
        acts.push(a);
    }
    Tape { acts, zs, n }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::csr::Csr;
    use crate::model::ModelConfig;
    use crate::partition::patterns::PartitionParams;
    use crate::serve::gcn::reference_forward;
    use crate::spmm::verify::assert_allclose;
    use crate::util::rng::Pcg;

    fn random_csr(seed: u64, n: usize) -> Csr {
        let mut rng = Pcg::seed_from(seed);
        let mut edges = vec![(0u32, 0u32, 1.0f32)];
        for r in 0..n {
            for _ in 0..rng.range(0, 6) {
                edges.push((r as u32, rng.range(0, n) as u32, rng.f32() + 0.1));
            }
        }
        Csr::from_edges(n, n, &edges).unwrap()
    }

    #[test]
    fn tape_logits_match_reference_forward() {
        let csr = random_csr(3, 40);
        let model = GcnModel::random(ModelConfig::gcn(6, 5, 3, 2), 9);
        let plan = SpmmPlan::build(csr.clone(), PartitionParams::default());
        let pool = ThreadPool::new(3);
        let mut rng = Pcg::seed_from(4);
        let x: Vec<f32> = (0..40 * 6).map(|_| rng.f32() - 0.5).collect();
        let mut phases = PhaseBreakdown::default();
        let tape = forward_with_tape(&plan, &pool, &model, &x, &mut phases);
        let want = reference_forward(&csr, &model, &x);
        assert_allclose(tape.logits(), &want, 1e-4, 1e-4, "tape logits");
        assert!(phases.fwd_spmm >= 0.0 && phases.fwd_dense >= 0.0);
    }

    #[test]
    fn tape_records_every_layer() {
        let csr = random_csr(5, 25);
        let model = GcnModel::random(ModelConfig::gcn(4, 3, 2, 3), 1);
        let plan = SpmmPlan::build(csr.clone(), PartitionParams::default());
        let pool = ThreadPool::new(2);
        let x = vec![0.5f32; 25 * 4];
        let tape =
            forward_with_tape(&plan, &pool, &model, &x, &mut PhaseBreakdown::default());
        assert_eq!(tape.zs.len(), 3);
        assert_eq!(tape.acts.len(), 3);
        // shapes: zs[l] is [n × din], acts[l] is [n × dout]
        for (l, &(din, dout)) in model.dims().iter().enumerate() {
            assert_eq!(tape.zs[l].len(), 25 * din);
            assert_eq!(tape.acts[l].len(), 25 * dout);
        }
        // z_0 really is Â·X
        let want = csr.spmm_dense(&x, 4);
        assert_allclose(&tape.zs[0], &want, 1e-4, 1e-4, "z0");
        // hidden activations are ReLU-clamped
        assert!(tape.acts[0].iter().all(|&v| v >= 0.0));
        assert!(tape.acts[1].iter().all(|&v| v >= 0.0));
    }
}
