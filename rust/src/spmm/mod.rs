//! Exact CPU executors for every partition schedule.
//!
//! These run the paper's schedules *literally* — block by block, warp by
//! warp, with per-block shared accumulators and global accumulation for
//! split rows — producing exact numerics that are checked against the
//! dense CSR reference. They are the correctness ground truth for the
//! partitioners and the behavioural model the GPU simulator's trace
//! generators are built on.
//!
//! [`microkernel`] is the performance-oriented exception: the
//! column-tiled inner loop the parallel executor
//! ([`crate::pipeline::ParallelBlockLevel`](crate::pipeline)) runs,
//! mapping the paper's combined-warp column sweep onto autovectorized
//! register tiles.

pub mod block_exec;
pub mod microkernel;
pub mod warp_exec;
pub mod verify;

pub use block_exec::{spmm_block_level, spmm_block_level_adaptive};
pub use microkernel::{
    accumulate_row, accumulate_row_select, accumulate_row_with, gather_row_with, gflops,
    select_kernel, spmm_flops, spmm_gflops, RowKernel, SimdLevel, LANES, SPARSE_DEG_MAX, TILE,
};
pub use verify::{allclose, max_abs_diff, spmm_block_level_counting, TrafficCounts};
pub use warp_exec::{spmm_warp_level, spmm_warp_level_adaptive};
