//! Exact CPU executors for every partition schedule.
//!
//! These run the paper's schedules *literally* — block by block, warp by
//! warp, with per-block shared accumulators and global accumulation for
//! split rows — producing exact numerics that are checked against the
//! dense CSR reference. They are the correctness ground truth for the
//! partitioners and the behavioural model the GPU simulator's trace
//! generators are built on.

pub mod block_exec;
pub mod warp_exec;
pub mod verify;

pub use block_exec::spmm_block_level;
pub use verify::{allclose, max_abs_diff};
pub use warp_exec::spmm_warp_level;
