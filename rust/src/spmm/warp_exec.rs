//! Exact executor for the warp-level (GNNAdvisor-style) partition.
//!
//! Each neighbour group is one warp: it loops over the dense column
//! dimension in 32-wide strides (the "inner loop" the paper's combined
//! warp removes) and accumulates its partial row into global memory
//! atomically (groups of the same row may run on different SMs).

use crate::graph::csr::Csr;
use crate::partition::warp_level::WarpPartition;
use crate::spmm::microkernel::{self, select_kernel, SimdLevel};

/// Execute `Y = A · X` via the warp-level schedule.
pub fn spmm_warp_level(csr: &Csr, wp: &WarpPartition, x: &[f32], f: usize) -> Vec<f32> {
    assert_eq!(x.len(), csr.n_cols * f, "X shape mismatch");
    assert_eq!(wp.n_rows, csr.n_rows, "partition/graph mismatch");
    let mut y = vec![0f32; csr.n_rows * f];
    for g in &wp.groups {
        let dst = g.row as usize;
        // warp-private partial row (registers / shared memory slice)
        let mut partial = vec![0f32; f];
        for i in g.loc..g.loc + g.len {
            let c = csr.col_idx[i as usize] as usize;
            let v = csr.vals[i as usize];
            let xrow = &x[c * f..(c + 1) * f];
            // inner column loop, 32 lanes at a time
            for k in 0..f {
                partial[k] += v * xrow[k];
            }
        }
        // global atomic accumulation
        let yrow = &mut y[dst * f..(dst + 1) * f];
        for k in 0..f {
            yrow[k] += partial[k];
        }
    }
    y
}

/// Warp-level executor with sparsity-adaptive kernel dispatch: each
/// neighbour group runs [`select_kernel`] on its *row's* total degree —
/// the same degree-bucket rule the block-level plan records — so short
/// rows take the gather kernel (axpy straight into their output row,
/// skipping the warp-private partial) and long rows keep the tiled
/// dense kernel. A group's nonzeros are contiguous (`loc .. loc+len`),
/// so both kernels consume its slice directly; accumulation into `y`
/// stays the "global atomic" analog of [`spmm_warp_level`].
pub fn spmm_warp_level_adaptive(
    csr: &Csr,
    wp: &WarpPartition,
    x: &[f32],
    f: usize,
    level: SimdLevel,
) -> Vec<f32> {
    assert_eq!(x.len(), csr.n_cols * f, "X shape mismatch");
    assert_eq!(wp.n_rows, csr.n_rows, "partition/graph mismatch");
    let mut y = vec![0f32; csr.n_rows * f];
    for g in &wp.groups {
        let dst = g.row as usize;
        let (lo, hi) = (g.loc as usize, (g.loc + g.len) as usize);
        let kern = select_kernel(csr.degree(dst));
        microkernel::accumulate_row_select(
            kern,
            level,
            &csr.col_idx[lo..hi],
            &csr.vals[lo..hi],
            x,
            f,
            &mut y[dst * f..(dst + 1) * f],
        );
    }
    y
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spmm::verify::assert_allclose;
    use crate::util::proptest;
    use crate::util::rng::Pcg;

    fn random_graph(rng: &mut Pcg, n: usize) -> Csr {
        let mut edges = Vec::new();
        for r in 0..n {
            for _ in 0..rng.range(0, 10) {
                edges.push((r as u32, rng.range(0, n) as u32, rng.f32() - 0.5));
            }
        }
        Csr::from_edges(n, n, &edges).unwrap()
    }

    #[test]
    fn matches_reference() {
        let mut rng = Pcg::seed_from(31);
        let csr = random_graph(&mut rng, 40);
        let wp = WarpPartition::build(&csr, 3);
        let f = 5;
        let x: Vec<f32> = (0..40 * f).map(|_| rng.f32() - 0.5).collect();
        let want = csr.spmm_dense(&x, f);
        let got = spmm_warp_level(&csr, &wp, &x, f);
        assert_allclose(&got, &want, 1e-5, 1e-5, "warp exec");
    }

    #[test]
    fn prop_warp_exec_equals_reference() {
        proptest::check("warp_exec_vs_ref", 0x3A9A, 25, |rng| {
            let n = rng.range(1, 80);
            let csr = random_graph(rng, n);
            let gs = *rng.choose(&[1usize, 2, 7, 32]);
            let wp = WarpPartition::build(&csr, gs);
            let f = rng.range(1, 9);
            let x: Vec<f32> = (0..n * f).map(|_| rng.f32() - 0.5).collect();
            let want = csr.spmm_dense(&x, f);
            let got = spmm_warp_level(&csr, &wp, &x, f);
            assert_allclose(&got, &want, 1e-4, 1e-4, "prop warp exec");
        });
    }

    #[test]
    fn prop_adaptive_warp_exec_equals_reference() {
        proptest::check("warp_exec_adaptive_vs_ref", 0x3A9B, 15, |rng| {
            let n = rng.range(1, 60);
            let csr = random_graph(rng, n);
            let gs = *rng.choose(&[1usize, 2, 7, 32]);
            let wp = WarpPartition::build(&csr, gs);
            let f = *rng.choose(&[1usize, 3, 8, 17, 33]);
            let x: Vec<f32> = (0..n * f).map(|_| rng.f32() - 0.5).collect();
            let want = csr.spmm_dense(&x, f);
            for level in [SimdLevel::Scalar, SimdLevel::Portable, SimdLevel::Arch] {
                let got = spmm_warp_level_adaptive(&csr, &wp, &x, f, level);
                assert_allclose(&got, &want, 1e-4, 1e-4, level.name());
            }
        });
    }

    #[test]
    fn agreement_between_schedules() {
        // warp-level and block-level executors agree on the same graph
        use crate::graph::degree::DegreeSorted;
        use crate::partition::block_level::BlockPartition;
        use crate::partition::patterns::PartitionParams;
        let mut rng = Pcg::seed_from(32);
        let csr = random_graph(&mut rng, 50);
        let f = 4;
        let x: Vec<f32> = (0..50 * f).map(|_| rng.f32() - 0.5).collect();
        let wp = WarpPartition::build(&csr, 4);
        let warp_y = spmm_warp_level(&csr, &wp, &x, f);
        let ds = DegreeSorted::new(&csr);
        let bp = BlockPartition::build(&ds.csr, PartitionParams { max_block_warps: 4, max_warp_nzs: 4 });
        let block_y = ds.unpermute_rows(
            &crate::spmm::block_exec::spmm_block_level(&ds.csr, &bp, &x, f),
            f,
        );
        assert_allclose(&block_y, &warp_y, 1e-4, 1e-4, "schedule agreement");
    }
}
