//! Numeric comparison helpers shared by executor and integration tests.

/// Maximum absolute element difference.
pub fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len(), "length mismatch: {} vs {}", a.len(), b.len());
    a.iter().zip(b.iter()).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
}

/// Elementwise |a-b| ≤ atol + rtol·|b| (numpy-style allclose).
pub fn allclose(a: &[f32], b: &[f32], atol: f32, rtol: f32) -> bool {
    if a.len() != b.len() {
        return false;
    }
    a.iter().zip(b.iter()).all(|(x, y)| (x - y).abs() <= atol + rtol * y.abs())
}

/// Panic with a helpful report if not allclose.
pub fn assert_allclose(a: &[f32], b: &[f32], atol: f32, rtol: f32, context: &str) {
    assert_eq!(a.len(), b.len(), "{context}: length {} vs {}", a.len(), b.len());
    for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
        let tol = atol + rtol * y.abs();
        assert!(
            (x - y).abs() <= tol,
            "{context}: element {i}: {x} vs {y} (|diff|={} > tol={tol})",
            (x - y).abs()
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_equal() {
        assert!(allclose(&[1.0, 2.0], &[1.0, 2.0], 0.0, 0.0));
        assert_eq!(max_abs_diff(&[1.0, 2.0], &[1.5, 2.0]), 0.5);
    }

    #[test]
    fn tolerances() {
        assert!(allclose(&[1.0001], &[1.0], 1e-3, 0.0));
        assert!(!allclose(&[1.01], &[1.0], 1e-3, 0.0));
        assert!(allclose(&[100.1], &[100.0], 0.0, 1e-2));
    }

    #[test]
    fn length_mismatch_false() {
        assert!(!allclose(&[1.0], &[1.0, 2.0], 1.0, 1.0));
    }

    #[test]
    #[should_panic(expected = "element 1")]
    fn assert_reports_index() {
        assert_allclose(&[1.0, 5.0], &[1.0, 1.0], 1e-6, 0.0, "test");
    }
}
