//! Numeric comparison helpers shared by executor and integration tests,
//! plus the instrumented *counting* executor that ground-truths the
//! analytic [`TrafficModel`](crate::pipeline::traffic::TrafficModel):
//! a scalar mirror of the parallel block-level schedule that counts
//! every load and store its inner loops actually issue.

use crate::partition::metadata::BLOCK_META_BYTES;
use crate::pipeline::plan::SpmmPlan;
use crate::spmm::microkernel::RowKernel;

/// Maximum absolute element difference.
pub fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len(), "length mismatch: {} vs {}", a.len(), b.len());
    a.iter().zip(b.iter()).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
}

/// Elementwise |a-b| ≤ atol + rtol·|b| (numpy-style allclose).
pub fn allclose(a: &[f32], b: &[f32], atol: f32, rtol: f32) -> bool {
    if a.len() != b.len() {
        return false;
    }
    a.iter().zip(b.iter()).all(|(x, y)| (x - y).abs() <= atol + rtol * y.abs())
}

/// Panic with a helpful report if not allclose.
pub fn assert_allclose(a: &[f32], b: &[f32], atol: f32, rtol: f32, context: &str) {
    assert_eq!(a.len(), b.len(), "{context}: length {} vs {}", a.len(), b.len());
    for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
        let tol = atol + rtol * y.abs();
        assert!(
            (x - y).abs() <= tol,
            "{context}: element {i}: {x} vs {y} (|diff|={} > tol={tol})",
            (x - y).abs()
        );
    }
}

/// Loads and stores observed by the instrumented counting executor, in
/// bytes, under the traffic-model convention (see
/// [`crate::pipeline::traffic`]): instruction-level accesses to the
/// plan arrays and the X/Y matrices; buffer zeroing excluded.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct TrafficCounts {
    pub bytes_read: u64,
    pub bytes_written: u64,
}

impl TrafficCounts {
    pub fn total(&self) -> u64 {
        self.bytes_read + self.bytes_written
    }
}

/// Debug scalar executor mirroring the parallel block-level schedule —
/// same block walk, same adaptive kernel dispatch, same split-row
/// partial windows and post-join reduction — with a byte counter on
/// every load and store the inner loops issue. The numerics come back
/// in original row order, identical in accumulation order to one shard
/// covering every block.
///
/// This is the measurement side of the analytic-vs-instrumented
/// equivalence tests: on any plan (split rows included — chunks carry
/// their actual nonzero count in the metadata), the counts must equal
/// [`SpmmPlan::traffic`]'s `bytes_read(f)`/`bytes_written(f)` exactly.
/// Debug/test tooling, not a hot path.
pub fn spmm_block_level_counting(
    plan: &SpmmPlan,
    x: &[f32],
    f: usize,
) -> (Vec<f32>, TrafficCounts) {
    let sorted = &plan.sorted.csr;
    let perm = &plan.sorted.perm;
    let bp = &plan.block;
    let deg_bound = bp.params.deg_bound();
    assert_eq!(x.len(), sorted.n_cols * f, "X shape mismatch");
    let mut y = vec![0f32; sorted.n_rows * f];
    let mut c = TrafficCounts::default();
    let fw = (f * 4) as u64; // one f-wide f32 vector access
    // split-row partial windows, reduced after the block walk
    let mut split_rows: Vec<u32> = Vec::new();
    let mut buf: Vec<f32> = Vec::new();
    for b in 0..bp.meta.len() {
        let m = bp.meta[b];
        let loc = m.loc as usize;
        c.bytes_read += BLOCK_META_BYTES as u64; // the int4 metadata record
        if m.is_split(deg_bound) {
            split_rows.push(m.row);
            buf.resize(buf.len() + f, 0.0); // zeroing: not counted
            let w = buf.len() - f;
            let nzs = m.split_nzs();
            // dense-shaped chunk: accumulate in registers, then one
            // f-wide RMW into the partial window
            let mut acc = vec![0f32; f];
            for i in loc..loc + nzs {
                c.bytes_read += 4 + 4; // col index + value
                let col = sorted.col_idx[i] as usize;
                let v = sorted.vals[i];
                c.bytes_read += fw; // gathered X row
                for k in 0..f {
                    acc[k] += v * x[col * f + k];
                }
            }
            c.bytes_read += fw; // partial window RMW: read …
            c.bytes_written += fw; // … and write back
            for k in 0..f {
                buf[w + k] += acc[k];
            }
        } else {
            let kern = plan.kernels.kernel_for(b);
            let deg = m.deg as usize;
            for row_i in 0..m.block_rows() {
                let s = loc + row_i * deg;
                let dst = perm[m.row as usize + row_i] as usize * f;
                if deg == 0 {
                    continue; // both kernels early-return: no dst touch
                }
                match kern {
                    RowKernel::DenseTiled => {
                        // register-tile accumulate, one dst RMW per row
                        let mut acc = vec![0f32; f];
                        for i in s..s + deg {
                            c.bytes_read += 4 + 4;
                            let col = sorted.col_idx[i] as usize;
                            let v = sorted.vals[i];
                            c.bytes_read += fw;
                            for k in 0..f {
                                acc[k] += v * x[col * f + k];
                            }
                        }
                        c.bytes_read += fw;
                        c.bytes_written += fw;
                        for k in 0..f {
                            y[dst + k] += acc[k];
                        }
                    }
                    RowKernel::SparseGather => {
                        // direct axpy: one dst RMW per nonzero
                        for i in s..s + deg {
                            c.bytes_read += 4 + 4;
                            let col = sorted.col_idx[i] as usize;
                            let v = sorted.vals[i];
                            c.bytes_read += fw;
                            c.bytes_read += fw;
                            c.bytes_written += fw;
                            for k in 0..f {
                                y[dst + k] += v * x[col * f + k];
                            }
                        }
                    }
                }
            }
        }
    }
    // post-join reduction: read each partial window, RMW the final row
    for (k, &srow) in split_rows.iter().enumerate() {
        let dst = perm[srow as usize] as usize * f;
        c.bytes_read += fw; // partial window
        c.bytes_read += fw; // y row RMW: read …
        c.bytes_written += fw; // … and write
        for j in 0..f {
            y[dst + j] += buf[k * f + j];
        }
    }
    (y, c)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::csr::Csr;
    use crate::partition::patterns::PartitionParams;
    use crate::util::rng::Pcg;

    #[test]
    fn exact_equal() {
        assert!(allclose(&[1.0, 2.0], &[1.0, 2.0], 0.0, 0.0));
        assert_eq!(max_abs_diff(&[1.0, 2.0], &[1.5, 2.0]), 0.5);
    }

    #[test]
    fn tolerances() {
        assert!(allclose(&[1.0001], &[1.0], 1e-3, 0.0));
        assert!(!allclose(&[1.01], &[1.0], 1e-3, 0.0));
        assert!(allclose(&[100.1], &[100.0], 0.0, 1e-2));
    }

    #[test]
    fn length_mismatch_false() {
        assert!(!allclose(&[1.0], &[1.0, 2.0], 1.0, 1.0));
    }

    #[test]
    #[should_panic(expected = "element 1")]
    fn assert_reports_index() {
        assert_allclose(&[1.0, 5.0], &[1.0, 1.0], 1e-6, 0.0, "test");
    }

    const WIDTHS: [usize; 5] = [1, 3, 16, 17, 33];

    fn x_of(n: usize, f: usize, seed: u64) -> Vec<f32> {
        let mut rng = Pcg::seed_from(seed);
        (0..n * f).map(|_| rng.f32() - 0.5).collect()
    }

    fn check_counts_match(plan: &SpmmPlan, label: &str) {
        for f in WIDTHS {
            let x = x_of(plan.original.n_cols, f, 42 + f as u64);
            let (y, counts) = spmm_block_level_counting(plan, &x, f);
            // the analytic model must match the instrumented executor
            // byte-for-byte — split rows included (chunk sizes are
            // exact in the metadata), so the documented bound is zero
            assert_eq!(
                counts.bytes_read,
                plan.traffic.bytes_read(f),
                "{label}: bytes_read at f={f}"
            );
            assert_eq!(
                counts.bytes_written,
                plan.traffic.bytes_written(f),
                "{label}: bytes_written at f={f}"
            );
            assert_eq!(counts.total(), plan.traffic.bytes_total(f), "{label}: total at f={f}");
            // and the counting executor must still be a correct SpMM
            assert_allclose(&y, &plan.original.spmm_dense(&x, f), 1e-4, 1e-4, label);
        }
    }

    /// Split-free plan exercising BOTH kernel variants (degrees straddle
    /// the gather crossover) plus empty rows, across all widths.
    #[test]
    fn analytic_model_matches_instrumented_executor_split_free() {
        let mut edges = Vec::new();
        for r in 0..60u32 {
            for c in 0..(r % 11) {
                edges.push((r, c, 0.5 + (c as f32) * 0.1));
            }
        }
        let plan = SpmmPlan::build(
            Csr::from_edges(60, 60, &edges).unwrap(),
            PartitionParams::default(),
        );
        let deg_bound = plan.params.deg_bound();
        assert!(plan.block.meta.iter().all(|m| !m.is_split(deg_bound)), "must be split-free");
        assert!(plan.kernels.n_sparse > 0 && plan.kernels.n_dense > 0, "need both variants");
        check_counts_match(&plan, "split-free");
    }

    /// Split rows under a tight partition (ragged tail chunks included):
    /// the model stays exact because each chunk's actual nonzero count
    /// is in the metadata.
    #[test]
    fn analytic_model_matches_instrumented_executor_with_splits() {
        let mut edges = Vec::new();
        let mut rng = Pcg::seed_from(7);
        for r in 0..50u32 {
            let deg = if r % 9 == 0 { 23 } else { rng.range(0, 6) as u32 };
            for _ in 0..deg {
                edges.push((r, rng.range(0, 50) as u32, rng.f32() + 0.1));
            }
        }
        let params = PartitionParams { max_block_warps: 2, max_warp_nzs: 2 };
        let plan = SpmmPlan::build(Csr::from_edges(50, 50, &edges).unwrap(), params);
        let deg_bound = plan.params.deg_bound();
        assert!(plan.block.meta.iter().any(|m| m.is_split(deg_bound)), "need split rows");
        check_counts_match(&plan, "with-splits");
    }
}
