//! Exact executor for the block-level partition — runs the Accel-GCN
//! schedule literally (paper §III-D "Summary and Further Enhancement").
//!
//! Three accumulation levels, mirroring the kernel's cache hierarchy:
//! 1. within a warp, threads of the combined warp cover the column
//!    dimension (here: an inner `f`-loop over a private register row);
//! 2. warps of a block accumulate into a **block-shared** buffer
//!    (CUDA `atomicAdd_block` into shared memory) — one row slot per
//!    block row;
//! 3. split-row blocks accumulate their partial results into the global
//!    output atomically (here: plain adds, since the executor is
//!    sequential per row).
//!
//! The result must equal the dense CSR reference bit-for-bit up to f32
//! addition reordering.

use crate::graph::csr::Csr;
use crate::partition::block_level::BlockPartition;
use crate::pipeline::plan::SpmmPlan;
use crate::spmm::microkernel::{self, SimdLevel};

/// Execute `Y = A_sorted · X` via the block-level schedule.
/// `x` is `[n_cols × f]` row-major; result rows are in the sorted domain.
pub fn spmm_block_level(sorted: &Csr, bp: &BlockPartition, x: &[f32], f: usize) -> Vec<f32> {
    assert_eq!(x.len(), sorted.n_cols * f, "X shape mismatch");
    assert_eq!(bp.n_rows, sorted.n_rows, "partition/graph mismatch");
    let deg_bound = bp.params.deg_bound();
    let mut y = vec![0f32; sorted.n_rows * f];

    for (b, m) in bp.meta.iter().enumerate() {
        if m.is_split(deg_bound) {
            // level 3: chunk of a long row → accumulate into global y
            let dst = m.row as usize;
            for t in bp.block_warp_tasks(b) {
                debug_assert!(t.needs_global_atomic);
                let yrow = &mut y[dst * f..(dst + 1) * f];
                for i in t.nz_start..t.nz_start + t.nz_len {
                    let c = sorted.col_idx[i] as usize;
                    let v = sorted.vals[i];
                    let xrow = &x[c * f..(c + 1) * f];
                    for k in 0..f {
                        yrow[k] += v * xrow[k];
                    }
                }
            }
        } else {
            // level 2: block-shared accumulator, one slot per block row
            // (padded to the column dimension like the shared-memory
            // array padded to a multiple of 32 in the paper)
            let rows = m.block_rows();
            let mut shared = vec![0f32; rows * f];
            for t in bp.block_warp_tasks(b) {
                let slot = (t.sorted_row - m.row) as usize;
                let srow = &mut shared[slot * f..(slot + 1) * f];
                for i in t.nz_start..t.nz_start + t.nz_len {
                    let c = sorted.col_idx[i] as usize;
                    let v = sorted.vals[i];
                    let xrow = &x[c * f..(c + 1) * f];
                    // level 1: combined warp covers the f columns with
                    // contiguous lanes
                    for k in 0..f {
                        srow[k] += v * xrow[k];
                    }
                }
            }
            // write back shared → global (coalesced store)
            let base = m.row as usize;
            y[base * f..(base + rows) * f].copy_from_slice(&shared);
        }
    }
    y
}

/// Sequential block-level executor honoring the plan's sparsity-
/// adaptive kernel schedule at an explicit SIMD level: each non-split
/// block's rows run the kernel shape
/// [`KernelSchedule::derive`](crate::pipeline::plan::KernelSchedule)
/// selected for that block (dense tiled or sparse gather); split-row
/// chunks always run the dense kernel into a global-accumulation row,
/// mirroring [`spmm_block_level`]'s level-3 path. Result rows are in
/// the **sorted** domain, exactly like [`spmm_block_level`].
pub fn spmm_block_level_adaptive(
    plan: &SpmmPlan,
    x: &[f32],
    f: usize,
    level: SimdLevel,
) -> Vec<f32> {
    let sorted = &plan.sorted.csr;
    let bp = &plan.block;
    assert_eq!(x.len(), sorted.n_cols * f, "X shape mismatch");
    let deg_bound = bp.params.deg_bound();
    let mut y = vec![0f32; sorted.n_rows * f];
    for (b, m) in bp.meta.iter().enumerate() {
        let loc = m.loc as usize;
        if m.is_split(deg_bound) {
            let dst = m.row as usize;
            let nzs = m.split_nzs();
            microkernel::accumulate_row_with(
                level,
                &sorted.col_idx[loc..loc + nzs],
                &sorted.vals[loc..loc + nzs],
                x,
                f,
                &mut y[dst * f..(dst + 1) * f],
            );
        } else {
            let kern = plan.kernels.kernel_for(b);
            let deg = m.deg as usize;
            for row_i in 0..m.block_rows() {
                let s = loc + row_i * deg;
                let dst = m.row as usize + row_i;
                microkernel::accumulate_row_select(
                    kern,
                    level,
                    &sorted.col_idx[s..s + deg],
                    &sorted.vals[s..s + deg],
                    x,
                    f,
                    &mut y[dst * f..(dst + 1) * f],
                );
            }
        }
    }
    y
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::degree::DegreeSorted;
    use crate::partition::patterns::PartitionParams;
    use crate::spmm::verify::assert_allclose;
    use crate::util::proptest;
    use crate::util::rng::Pcg;

    fn random_graph(rng: &mut Pcg, n: usize, heavy_tail: bool) -> Csr {
        let mut edges = Vec::new();
        for r in 0..n {
            let d = if heavy_tail && rng.f64() < 0.05 {
                rng.range(0, 3 * n / 2 + 2) // can exceed deg_bound for small params
            } else {
                rng.range(0, 8)
            };
            for _ in 0..d {
                edges.push((r as u32, rng.range(0, n) as u32, rng.f32() - 0.5));
            }
        }
        Csr::from_edges(n, n, &edges).unwrap()
    }

    #[test]
    fn matches_reference_small() {
        let mut rng = Pcg::seed_from(21);
        let csr = random_graph(&mut rng, 30, false);
        let ds = DegreeSorted::new(&csr);
        let bp = BlockPartition::build(&ds.csr, PartitionParams { max_block_warps: 2, max_warp_nzs: 2 });
        let f = 4;
        let x: Vec<f32> = (0..30 * f).map(|_| rng.f32() - 0.5).collect();
        let want = ds.csr.spmm_dense(&x, f);
        let got = spmm_block_level(&ds.csr, &bp, &x, f);
        assert_allclose(&got, &want, 1e-5, 1e-5, "block exec");
    }

    #[test]
    fn split_rows_accumulate_correctly() {
        // single row of degree 20 with bound 4: 5 chunks, all into row 0
        let params = PartitionParams { max_block_warps: 2, max_warp_nzs: 2 };
        let edges: Vec<(u32, u32, f32)> = (0..20).map(|c| (0u32, c, (c + 1) as f32)).collect();
        let csr = Csr::from_edges(1, 20, &edges).unwrap();
        let bp = BlockPartition::build(&csr, params);
        assert!(bp.meta.len() > 1);
        let f = 2;
        let x: Vec<f32> = (0..20 * f).map(|i| i as f32 * 0.1).collect();
        let want = csr.spmm_dense(&x, f);
        let got = spmm_block_level(&csr, &bp, &x, f);
        assert_allclose(&got, &want, 1e-3, 1e-5, "split rows");
    }

    #[test]
    fn zero_rows_stay_zero() {
        let params = PartitionParams::default();
        let csr = Csr::from_edges(4, 4, &[(2, 1, 3.0)]).unwrap();
        let ds = DegreeSorted::new(&csr);
        let bp = BlockPartition::build(&ds.csr, params);
        let f = 3;
        let x = vec![1.0f32; 4 * f];
        let y = spmm_block_level(&ds.csr, &bp, &x, f);
        // sorted order puts the deg-1 row last
        for r in 0..3 {
            assert_eq!(&y[r * f..(r + 1) * f], &[0.0, 0.0, 0.0], "row {r}");
        }
        assert_eq!(&y[3 * f..4 * f], &[3.0, 3.0, 3.0]);
    }

    #[test]
    fn prop_block_exec_equals_reference() {
        proptest::check("block_exec_vs_ref", 0x5B0C, 25, |rng| {
            let n = rng.range(1, 70);
            let csr = random_graph(rng, n, true);
            let ds = DegreeSorted::new(&csr);
            let params = PartitionParams {
                max_block_warps: *rng.choose(&[1usize, 2, 3, 4, 12]),
                max_warp_nzs: *rng.choose(&[1usize, 2, 4, 32]),
            };
            let bp = BlockPartition::build(&ds.csr, params);
            let f = rng.range(1, 10);
            let x: Vec<f32> = (0..n * f).map(|_| rng.f32() - 0.5).collect();
            let want = ds.csr.spmm_dense(&x, f);
            let got = spmm_block_level(&ds.csr, &bp, &x, f);
            assert_allclose(&got, &want, 1e-4, 1e-4, "prop block exec");
        });
    }

    /// The adaptive sequential executor agrees with the literal one —
    /// and with the dense reference — at every SIMD level, on graphs
    /// mixing gather-territory rows, dense rows, and split rows.
    #[test]
    fn prop_adaptive_exec_equals_reference() {
        proptest::check("block_exec_adaptive", 0x5B0E, 12, |rng| {
            let n = rng.range(1, 60);
            let csr = random_graph(rng, n, true);
            let params = PartitionParams {
                max_block_warps: *rng.choose(&[1usize, 2, 4]),
                max_warp_nzs: *rng.choose(&[1usize, 2, 8]),
            };
            let plan = SpmmPlan::build(csr, params);
            let f = *rng.choose(&[1usize, 3, 16, 17, 33]);
            let x: Vec<f32> =
                (0..plan.original.n_cols * f).map(|_| rng.f32() - 0.5).collect();
            let want = spmm_block_level(&plan.sorted.csr, &plan.block, &x, f);
            for level in [SimdLevel::Scalar, SimdLevel::Portable, SimdLevel::Arch] {
                let got = spmm_block_level_adaptive(&plan, &x, f, level);
                assert_allclose(&got, &want, 1e-4, 1e-4, level.name());
            }
        });
    }

    #[test]
    fn prop_full_pipeline_unpermuted() {
        // degree-sort → partition → execute → unpermute == plain SpMM
        proptest::check("block_exec_pipeline", 0x5B0D, 15, |rng| {
            let n = rng.range(1, 50);
            let csr = random_graph(rng, n, true);
            let ds = DegreeSorted::new(&csr);
            let bp = BlockPartition::build(&ds.csr, PartitionParams { max_block_warps: 4, max_warp_nzs: 4 });
            let f = rng.range(1, 6);
            let x: Vec<f32> = (0..n * f).map(|_| rng.f32() - 0.5).collect();
            let got = ds.unpermute_rows(&spmm_block_level(&ds.csr, &bp, &x, f), f);
            let want = csr.spmm_dense(&x, f);
            assert_allclose(&got, &want, 1e-4, 1e-4, "pipeline");
        });
    }
}
