//! Column-tiled, SIMD-dispatched SpMM microkernels — the CPU analog of
//! the paper's combined-warp strategy (§III-C), now with explicit f32
//! lanes and a sparsity-adaptive second kernel shape.
//!
//! On the GPU, a combined warp's 32 lanes sweep the dense column
//! dimension in lockstep so every global load is coalesced. The CPU
//! translation: walk the columns in fixed-width tiles of [`TILE`]
//! floats, accumulating each tile in vector registers — tile width ↔
//! warp span. Three lane strategies implement that sweep, selected at
//! runtime ([`SimdLevel`]):
//!
//! * [`SimdLevel::Scalar`] — the PR 4 baseline: a `[f32; TILE]` stack
//!   accumulator LLVM autovectorizes. Kept as the measured floor.
//! * [`SimdLevel::Portable`] — explicit 8-wide unrolled lanes (two
//!   independent `[f32; LANES]` accumulators per tile, `wide`-style
//!   f32x8 written by hand). Identical per-lane operation order to the
//!   scalar path, so the two are **bit-for-bit** equal.
//! * [`SimdLevel::Arch`] — arch intrinsics behind runtime feature
//!   detection: AVX2+FMA on x86_64 (`_mm256_fmadd_ps`, two 8-lane
//!   vectors per tile), NEON on aarch64 (`vfmaq_f32`, four 4-lane
//!   vectors per tile). FMA contracts the multiply-add into a single
//!   rounding, so arch results differ from scalar/portable within
//!   [`ARCH_REL_TOL`] relative — the documented tolerance every
//!   equivalence proptest uses.
//!
//! Columns beyond the last full tile (`f % TILE != 0`) take the ragged
//! tail path: runtime-bounded lanes, shared by all levels. Both paths
//! *accumulate* into `dst` (`+=`), so a destination row can absorb
//! several nonzero ranges (multiple warp tasks of one row, or split-row
//! chunks) in sequence — the contract every executor programs against.
//!
//! ## Two kernel shapes ([`RowKernel`])
//!
//! FlexVector's observation holds on CPUs too: one kernel shape loses
//! on varying-sparsity graphs. For short rows the dense tile's
//! accumulator round-trip (zero `acc`, sum into `acc`, add `acc` into
//! `dst`) costs more than the row's arithmetic, so rows with
//! `deg ≤ SPARSE_DEG_MAX` run [`gather_row_with`] instead: each
//! nonzero's X row is axpy'd straight into `dst`, no tile accumulator
//! at all. [`select_kernel`] is the pure degree → kernel rule; the plan
//! records the choice per block
//! ([`KernelSchedule`](crate::pipeline::plan::KernelSchedule)) and the
//! executors honor it.

use std::sync::OnceLock;

/// Column-tile width, in f32 lanes. 16 floats = one 64-byte cache line
/// = two AVX2 / one AVX-512 vector — wide enough to saturate the FMA
/// ports, narrow enough that one accumulator tile always fits the
/// register file.
pub const TILE: usize = 16;

/// Portable-SIMD lane width: one f32x8 (half a [`TILE`]).
pub const LANES: usize = 8;

/// Dense/sparse crossover degree: rows with at most this many nonzeros
/// run the sparse gather kernel (the tile-accumulator setup dominates
/// below it). Chosen so the gather path covers the power-law mass of
/// degree 1–4 rows; the microkernel bench sweeps degree skew so the
/// crossover is measured, not guessed.
pub const SPARSE_DEG_MAX: usize = 4;

/// Relative tolerance between the arch-SIMD (FMA-contracted) results
/// and the scalar/portable (separate multiply + add) results. One FMA
/// saves one rounding per (nonzero, lane) pair; over any realistic row
/// the relative drift stays far below this bound.
pub const ARCH_REL_TOL: f32 = 1e-5;

/// Lane strategy for the inner column sweep, in ascending order of
/// hardware assumption.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum SimdLevel {
    /// Autovectorized stack-array tiles (the PR 4 baseline).
    Scalar,
    /// Explicit 8-wide unrolled lanes; bit-identical to `Scalar`.
    Portable,
    /// AVX2+FMA (x86_64) / NEON (aarch64) intrinsics. Falls back to
    /// `Portable` at dispatch when the host lacks the features
    /// ([`SimdLevel::effective`]), so passing `Arch` is always safe.
    Arch,
}

impl SimdLevel {
    /// Stable identifier used in bench output, JSON, and the serve
    /// metrics footer.
    pub fn name(self) -> &'static str {
        match self {
            SimdLevel::Scalar => "scalar",
            SimdLevel::Portable => "portable-simd",
            SimdLevel::Arch => arch::NAME,
        }
    }

    /// Whether this level can actually execute on the running host.
    pub fn available(self) -> bool {
        match self {
            SimdLevel::Scalar | SimdLevel::Portable => true,
            SimdLevel::Arch => arch::available(),
        }
    }

    /// The level the dispatcher will really run: `Arch` degrades to
    /// `Portable` when the host lacks the features, everything else is
    /// itself. All public kernel entry points call this, so an
    /// unsupported `Arch` request is never unsound — just portable.
    pub fn effective(self) -> SimdLevel {
        if self == SimdLevel::Arch && !arch::available() {
            SimdLevel::Portable
        } else {
            self
        }
    }

    /// Fresh hardware probe (no caching): the widest available level.
    pub fn detect() -> SimdLevel {
        if arch::available() {
            SimdLevel::Arch
        } else {
            SimdLevel::Portable
        }
    }

    /// The process-wide default level, computed once: the
    /// `ACCEL_GCN_SIMD` environment variable (`scalar` | `portable` |
    /// `arch`/`native`) if set — CI forces `portable` to prove the
    /// fallback — otherwise [`SimdLevel::detect`]. A forced `arch` on a
    /// host without the features degrades to portable at dispatch.
    pub fn best() -> SimdLevel {
        static BEST: OnceLock<SimdLevel> = OnceLock::new();
        *BEST.get_or_init(|| match std::env::var("ACCEL_GCN_SIMD").ok().as_deref() {
            Some("scalar") => SimdLevel::Scalar,
            Some("portable") => SimdLevel::Portable,
            Some("arch") | Some("native") => SimdLevel::Arch,
            _ => SimdLevel::detect(),
        })
    }
}

/// Which kernel shape a row (or a whole degree bucket) runs.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum RowKernel {
    /// Column-tiled accumulator kernel ([`accumulate_row_with`]) — the
    /// dense-row shape: amortizes the accumulator round-trip over many
    /// nonzeros.
    DenseTiled,
    /// Direct-axpy gather kernel ([`gather_row_with`]) — the sparse-row
    /// shape: no tile accumulator, each nonzero streams straight into
    /// the destination row.
    SparseGather,
}

impl RowKernel {
    pub fn name(self) -> &'static str {
        match self {
            RowKernel::DenseTiled => "dense-tiled",
            RowKernel::SparseGather => "sparse-gather",
        }
    }
}

/// The dense/sparse selection rule: a pure function of row degree, so
/// plan build, the delta patch path, and a from-scratch rebuild always
/// agree (the patch proptests assert schedule equality).
#[inline]
pub fn select_kernel(deg: usize) -> RowKernel {
    if deg <= SPARSE_DEG_MAX {
        RowKernel::SparseGather
    } else {
        RowKernel::DenseTiled
    }
}

// ---------------------------------------------------------------------
// Scalar (autovectorized) tiles — the PR 4 baseline, byte-for-byte.
// ---------------------------------------------------------------------

/// `dst[t0 .. t0+TILE] += Σ_i vals[i] · x[cols[i]·f + t0 ..][..TILE]`
/// — one full-width tile, constant trip counts throughout.
#[inline]
fn tile_full(cols: &[u32], vals: &[f32], x: &[f32], f: usize, t0: usize, dst: &mut [f32]) {
    let mut acc = [0f32; TILE];
    for (&c, &v) in cols.iter().zip(vals) {
        let base = c as usize * f + t0;
        let xt: &[f32; TILE] = x[base..base + TILE].try_into().expect("tile in bounds");
        for j in 0..TILE {
            acc[j] += v * xt[j];
        }
    }
    let d: &mut [f32; TILE] = (&mut dst[t0..t0 + TILE]).try_into().expect("tile in bounds");
    for j in 0..TILE {
        d[j] += acc[j];
    }
}

/// The ragged tail: the final `f - t0 < TILE` columns, runtime-bounded
/// lanes over the same stack accumulator. Shared by every [`SimdLevel`]
/// (the tail is a bounded fraction of the work; keeping one copy keeps
/// scalar and portable bit-identical on ragged widths too).
#[inline]
fn tile_tail(cols: &[u32], vals: &[f32], x: &[f32], f: usize, t0: usize, dst: &mut [f32]) {
    let tw = f - t0;
    debug_assert!(tw > 0 && tw < TILE);
    let mut acc = [0f32; TILE];
    for (&c, &v) in cols.iter().zip(vals) {
        let base = c as usize * f + t0;
        for (a, &xv) in acc[..tw].iter_mut().zip(&x[base..base + tw]) {
            *a += v * xv;
        }
    }
    for (d, a) in dst[t0..].iter_mut().zip(&acc[..tw]) {
        *d += *a;
    }
}

// ---------------------------------------------------------------------
// Portable 8-wide tiles — hand-written f32x8, no arch assumptions.
// ---------------------------------------------------------------------

/// Full tile as two independent 8-lane accumulators (two f32x8
/// registers). Per-lane operation order matches [`tile_full`] exactly,
/// so the result is bit-identical to the scalar path.
#[inline]
fn tile_full_portable(cols: &[u32], vals: &[f32], x: &[f32], f: usize, t0: usize, dst: &mut [f32]) {
    let mut acc0 = [0f32; LANES];
    let mut acc1 = [0f32; LANES];
    for (&c, &v) in cols.iter().zip(vals) {
        let base = c as usize * f + t0;
        let xt: &[f32; TILE] = x[base..base + TILE].try_into().expect("tile in bounds");
        for j in 0..LANES {
            acc0[j] += v * xt[j];
        }
        for j in 0..LANES {
            acc1[j] += v * xt[LANES + j];
        }
    }
    let d: &mut [f32; TILE] = (&mut dst[t0..t0 + TILE]).try_into().expect("tile in bounds");
    for j in 0..LANES {
        d[j] += acc0[j];
    }
    for j in 0..LANES {
        d[LANES + j] += acc1[j];
    }
}

/// `dst[j] += v · xrow[j]` in 8-lane chunks plus a scalar tail — the
/// portable axpy the sparse gather kernel streams through.
#[inline]
fn axpy_portable(v: f32, xrow: &[f32], dst: &mut [f32]) {
    let n = dst.len();
    debug_assert_eq!(xrow.len(), n);
    let mut j = 0usize;
    while j + LANES <= n {
        let xt: &[f32; LANES] = xrow[j..j + LANES].try_into().expect("chunk in bounds");
        let d: &mut [f32; LANES] = (&mut dst[j..j + LANES]).try_into().expect("chunk in bounds");
        for k in 0..LANES {
            d[k] += v * xt[k];
        }
        j += LANES;
    }
    for k in j..n {
        dst[k] += v * xrow[k];
    }
}

#[inline]
fn axpy_scalar(v: f32, xrow: &[f32], dst: &mut [f32]) {
    for (d, &xv) in dst.iter_mut().zip(xrow) {
        *d += v * xv;
    }
}

// ---------------------------------------------------------------------
// Arch-gated intrinsics: AVX2+FMA on x86_64, NEON on aarch64.
// ---------------------------------------------------------------------

#[cfg(target_arch = "x86_64")]
mod arch {
    use super::{LANES, TILE};
    use std::arch::x86_64::*;

    pub const NAME: &str = "avx2";

    pub fn available() -> bool {
        is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma")
    }

    /// One full tile: two 8-lane FMA accumulators.
    ///
    /// # Safety
    /// AVX2+FMA must be available ([`available`]); the caller upholds
    /// the tile contract (`t0 + TILE ≤ f`, every `cols[i]` a valid row
    /// of `x`, `dst.len() == f`).
    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn tile_full(
        cols: &[u32],
        vals: &[f32],
        x: &[f32],
        f: usize,
        t0: usize,
        dst: &mut [f32],
    ) {
        let mut acc0 = _mm256_setzero_ps();
        let mut acc1 = _mm256_setzero_ps();
        for (&c, &v) in cols.iter().zip(vals) {
            let base = c as usize * f + t0;
            debug_assert!(base + TILE <= x.len());
            let vv = _mm256_set1_ps(v);
            let p = x.as_ptr().add(base);
            acc0 = _mm256_fmadd_ps(vv, _mm256_loadu_ps(p), acc0);
            acc1 = _mm256_fmadd_ps(vv, _mm256_loadu_ps(p.add(LANES)), acc1);
        }
        debug_assert!(t0 + TILE <= dst.len());
        let d = dst.as_mut_ptr().add(t0);
        _mm256_storeu_ps(d, _mm256_add_ps(_mm256_loadu_ps(d), acc0));
        _mm256_storeu_ps(
            d.add(LANES),
            _mm256_add_ps(_mm256_loadu_ps(d.add(LANES)), acc1),
        );
    }

    /// `dst += v · xrow`, 8-lane FMA chunks + scalar tail.
    ///
    /// # Safety
    /// AVX2+FMA must be available; `xrow.len() == dst.len()`.
    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn axpy(v: f32, xrow: &[f32], dst: &mut [f32]) {
        let n = dst.len();
        debug_assert_eq!(xrow.len(), n);
        let vv = _mm256_set1_ps(v);
        let mut j = 0usize;
        while j + LANES <= n {
            let d = dst.as_mut_ptr().add(j);
            let r = _mm256_fmadd_ps(vv, _mm256_loadu_ps(xrow.as_ptr().add(j)), _mm256_loadu_ps(d));
            _mm256_storeu_ps(d, r);
            j += LANES;
        }
        for k in j..n {
            *dst.get_unchecked_mut(k) += v * xrow.get_unchecked(k);
        }
    }
}

#[cfg(target_arch = "aarch64")]
mod arch {
    use super::TILE;
    use std::arch::aarch64::*;

    pub const NAME: &str = "neon";

    /// NEON is part of the aarch64 baseline.
    pub fn available() -> bool {
        true
    }

    /// One full tile: four 4-lane FMA accumulators.
    ///
    /// # Safety
    /// Caller upholds the tile contract (`t0 + TILE ≤ f`, every
    /// `cols[i]` a valid row of `x`, `dst.len() == f`).
    pub unsafe fn tile_full(
        cols: &[u32],
        vals: &[f32],
        x: &[f32],
        f: usize,
        t0: usize,
        dst: &mut [f32],
    ) {
        let mut acc = [vdupq_n_f32(0.0); 4];
        for (&c, &v) in cols.iter().zip(vals) {
            let base = c as usize * f + t0;
            debug_assert!(base + TILE <= x.len());
            let vv = vdupq_n_f32(v);
            let p = x.as_ptr().add(base);
            for (k, a) in acc.iter_mut().enumerate() {
                *a = vfmaq_f32(*a, vv, vld1q_f32(p.add(4 * k)));
            }
        }
        debug_assert!(t0 + TILE <= dst.len());
        let d = dst.as_mut_ptr().add(t0);
        for (k, a) in acc.iter().enumerate() {
            let dp = d.add(4 * k);
            vst1q_f32(dp, vaddq_f32(vld1q_f32(dp), *a));
        }
    }

    /// `dst += v · xrow`, 4-lane FMA chunks + scalar tail.
    ///
    /// # Safety
    /// `xrow.len() == dst.len()`.
    pub unsafe fn axpy(v: f32, xrow: &[f32], dst: &mut [f32]) {
        let n = dst.len();
        debug_assert_eq!(xrow.len(), n);
        let vv = vdupq_n_f32(v);
        let mut j = 0usize;
        while j + 4 <= n {
            let d = dst.as_mut_ptr().add(j);
            vst1q_f32(d, vfmaq_f32(vld1q_f32(d), vv, vld1q_f32(xrow.as_ptr().add(j))));
            j += 4;
        }
        for k in j..n {
            *dst.get_unchecked_mut(k) += v * xrow.get_unchecked(k);
        }
    }
}

#[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
mod arch {
    pub const NAME: &str = "arch-simd";

    pub fn available() -> bool {
        false
    }

    /// # Safety
    /// Never called: [`available`] is false, so dispatch degrades
    /// `Arch` to `Portable` before reaching here.
    pub unsafe fn tile_full(_: &[u32], _: &[f32], _: &[f32], _: usize, _: usize, _: &mut [f32]) {
        unreachable!("no arch SIMD on this target");
    }

    /// # Safety
    /// Never called (see [`tile_full`]).
    pub unsafe fn axpy(_: f32, _: &[f32], _: &mut [f32]) {
        unreachable!("no arch SIMD on this target");
    }
}

// ---------------------------------------------------------------------
// Public kernel entry points.
// ---------------------------------------------------------------------

/// Accumulate one sparse row's contribution into its dense output row:
/// `dst[0..f] += Σ_i vals[i] · X[cols[i]]` with `X` row-major
/// `[n_cols × f]`. `cols`/`vals` are the row's (or row chunk's) nonzero
/// slice; `dst` is the full `f`-wide destination row. Runs the dense
/// tiled kernel at the process-wide best [`SimdLevel`].
#[inline]
pub fn accumulate_row(cols: &[u32], vals: &[f32], x: &[f32], f: usize, dst: &mut [f32]) {
    accumulate_row_with(SimdLevel::best(), cols, vals, x, f, dst);
}

/// The dense tiled kernel at an explicit [`SimdLevel`] — full tiles at
/// the requested lane strategy, ragged tail shared. `Arch` degrades to
/// `Portable` on hosts without the features.
pub fn accumulate_row_with(
    level: SimdLevel,
    cols: &[u32],
    vals: &[f32],
    x: &[f32],
    f: usize,
    dst: &mut [f32],
) {
    debug_assert_eq!(cols.len(), vals.len());
    debug_assert_eq!(dst.len(), f);
    if cols.is_empty() || f == 0 {
        return;
    }
    let level = level.effective();
    let mut t0 = 0usize;
    while t0 + TILE <= f {
        match level {
            SimdLevel::Scalar => tile_full(cols, vals, x, f, t0, dst),
            SimdLevel::Portable => tile_full_portable(cols, vals, x, f, t0, dst),
            // SAFETY: `effective()` guarantees the features are present;
            // the tile contract is upheld by the bounds-checked slices
            // the scalar path uses on the same indices.
            SimdLevel::Arch => unsafe { arch::tile_full(cols, vals, x, f, t0, dst) },
        }
        t0 += TILE;
    }
    if t0 < f {
        tile_tail(cols, vals, x, f, t0, dst);
    }
}

/// The sparse gather kernel: `dst[0..f] += Σ_i vals[i] · X[cols[i]]`
/// with no tile accumulator — each nonzero's X row is axpy'd straight
/// into `dst`. Wins on short rows (`deg ≤ SPARSE_DEG_MAX`) where the
/// dense kernel's accumulator round-trip dominates; identical contract
/// otherwise (accumulates, any `f`, empty-input no-op).
pub fn gather_row_with(
    level: SimdLevel,
    cols: &[u32],
    vals: &[f32],
    x: &[f32],
    f: usize,
    dst: &mut [f32],
) {
    debug_assert_eq!(cols.len(), vals.len());
    debug_assert_eq!(dst.len(), f);
    if f == 0 {
        return;
    }
    let level = level.effective();
    for (&c, &v) in cols.iter().zip(vals) {
        let base = c as usize * f;
        let xrow = &x[base..base + f];
        match level {
            SimdLevel::Scalar => axpy_scalar(v, xrow, dst),
            SimdLevel::Portable => axpy_portable(v, xrow, dst),
            // SAFETY: `effective()` guarantees the features; slice
            // lengths are equal by construction.
            SimdLevel::Arch => unsafe { arch::axpy(v, xrow, dst) },
        }
    }
}

/// Dispatch one row through the selected kernel shape at the given lane
/// strategy — the single entry point the adaptive executors call.
#[inline]
pub fn accumulate_row_select(
    kernel: RowKernel,
    level: SimdLevel,
    cols: &[u32],
    vals: &[f32],
    x: &[f32],
    f: usize,
    dst: &mut [f32],
) {
    match kernel {
        RowKernel::DenseTiled => accumulate_row_with(level, cols, vals, x, f, dst),
        RowKernel::SparseGather => gather_row_with(level, cols, vals, x, f, dst),
    }
}

// ---------------------------------------------------------------------
// FLOP accounting — the one home for every GFLOP/s computation.
// ---------------------------------------------------------------------

/// Floating-point operations of one SpMM: a multiply and an add per
/// (nonzero, column) pair — the GFLOP/s numerator used by the
/// microkernel bench and the serve metrics.
pub fn spmm_flops(nnz: usize, f: usize) -> f64 {
    2.0 * nnz as f64 * f as f64
}

/// `flops / secs` in GFLOP/s, guarded against zero wall time — the one
/// divider every bench table and serve metric goes through (previously
/// copy-pasted across `bench/` and `serve`).
pub fn gflops(flops: f64, secs: f64) -> f64 {
    flops / secs.max(1e-12) / 1e9
}

/// Achieved throughput of one SpMM: [`spmm_flops`] over wall time.
pub fn spmm_gflops(nnz: usize, f: usize, secs: f64) -> f64 {
    gflops(spmm_flops(nnz, f), secs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest;
    use crate::util::rng::Pcg;

    const ALL_LEVELS: [SimdLevel; 3] = [SimdLevel::Scalar, SimdLevel::Portable, SimdLevel::Arch];

    /// The definitionally-obvious scalar version the tiled kernel must
    /// reproduce (up to f32 addition reordering across tiles — exact
    /// here, since each output lane's sum keeps nonzero order).
    fn naive(cols: &[u32], vals: &[f32], x: &[f32], f: usize, dst: &mut [f32]) {
        for (&c, &v) in cols.iter().zip(vals) {
            for k in 0..f {
                dst[k] += v * x[c as usize * f + k];
            }
        }
    }

    fn random_row(rng: &mut Pcg, f: usize, n_cols: usize) -> (Vec<u32>, Vec<f32>, Vec<f32>) {
        let x: Vec<f32> = (0..n_cols * f).map(|_| rng.f32() - 0.5).collect();
        let nnz = rng.range(0, 25);
        let cols: Vec<u32> = (0..nnz).map(|_| rng.range(0, n_cols) as u32).collect();
        let vals: Vec<f32> = (0..nnz).map(|_| rng.f32() - 0.5).collect();
        (cols, vals, x)
    }

    #[test]
    fn matches_naive_across_widths_and_levels() {
        // full tiles, ragged tails, and sub-tile widths, at every level
        for &f in &[1usize, 2, 3, 15, 16, 17, 31, 32, 33, 48, 64, 96, 100, 128] {
            let mut rng = Pcg::seed_from(f as u64 ^ 0xA11);
            let (cols, vals, x) = random_row(&mut rng, f, 37);
            let mut want = vec![0.1f32; f]; // nonzero start: += must preserve it
            naive(&cols, &vals, &x, f, &mut want);
            for level in ALL_LEVELS {
                let mut got = vec![0.1f32; f];
                accumulate_row_with(level, &cols, &vals, &x, f, &mut got);
                for (a, b) in got.iter().zip(&want) {
                    assert!(
                        (a - b).abs() <= 1e-4 * (1.0 + b.abs()),
                        "{}: f={f}: {a} vs {b}",
                        level.name()
                    );
                }
            }
        }
    }

    #[test]
    fn gather_matches_naive_across_widths_and_levels() {
        for &f in &[1usize, 3, 8, 15, 16, 17, 33] {
            let mut rng = Pcg::seed_from(f as u64 ^ 0x6A7);
            let (cols, vals, x) = random_row(&mut rng, f, 29);
            let mut want = vec![0.25f32; f];
            naive(&cols, &vals, &x, f, &mut want);
            for level in ALL_LEVELS {
                let mut got = vec![0.25f32; f];
                gather_row_with(level, &cols, &vals, &x, f, &mut got);
                for (a, b) in got.iter().zip(&want) {
                    assert!(
                        (a - b).abs() <= 1e-4 * (1.0 + b.abs()),
                        "gather {}: f={f}: {a} vs {b}",
                        level.name()
                    );
                }
            }
        }
    }

    /// The satellite equivalence property: scalar and portable are
    /// bit-for-bit; arch is within the documented [`ARCH_REL_TOL`]
    /// (trivially bit-equal where `Arch` degrades to `Portable`).
    /// Covers the required f set, empty rows, and both kernel shapes.
    #[test]
    fn prop_levels_equivalent() {
        proptest::check("simd_levels_equivalent", 0x51D4, 40, |rng| {
            let f = *rng.choose(&[1usize, 3, 8, 16, 17, 33]);
            let n_cols = rng.range(1, 40);
            let (cols, vals, x) = random_row(rng, f, n_cols);
            for kernel in [RowKernel::DenseTiled, RowKernel::SparseGather] {
                let mut scalar = vec![0f32; f];
                let mut portable = vec![0f32; f];
                let mut arch = vec![0f32; f];
                accumulate_row_select(kernel, SimdLevel::Scalar, &cols, &vals, &x, f, &mut scalar);
                accumulate_row_select(
                    kernel,
                    SimdLevel::Portable,
                    &cols,
                    &vals,
                    &x,
                    f,
                    &mut portable,
                );
                accumulate_row_select(kernel, SimdLevel::Arch, &cols, &vals, &x, f, &mut arch);
                for j in 0..f {
                    assert_eq!(
                        scalar[j].to_bits(),
                        portable[j].to_bits(),
                        "{:?} lane {j}: scalar vs portable must be bit-identical",
                        kernel
                    );
                    let (a, b) = (arch[j], scalar[j]);
                    assert!(
                        (a - b).abs() <= ARCH_REL_TOL * (1.0 + b.abs()),
                        "{:?} lane {j}: arch {a} vs scalar {b} beyond ARCH_REL_TOL",
                        kernel
                    );
                }
            }
        });
    }

    #[test]
    fn dense_and_sparse_kernels_agree_from_zero() {
        // both shapes on the same row from a zeroed dst: same sums
        let mut rng = Pcg::seed_from(0xD5A);
        for &f in &[1usize, 8, 16, 17, 33] {
            let (cols, vals, x) = random_row(&mut rng, f, 23);
            for level in ALL_LEVELS {
                let mut dense = vec![0f32; f];
                let mut sparse = vec![0f32; f];
                accumulate_row_with(level, &cols, &vals, &x, f, &mut dense);
                gather_row_with(level, &cols, &vals, &x, f, &mut sparse);
                for (a, b) in dense.iter().zip(&sparse) {
                    assert!(
                        (a - b).abs() <= ARCH_REL_TOL * (1.0 + b.abs()),
                        "{}: dense {a} vs sparse {b}",
                        level.name()
                    );
                }
            }
        }
    }

    #[test]
    fn empty_inputs_are_noops_at_every_level() {
        let x = [1.0f32; 8];
        for level in ALL_LEVELS {
            let mut dst = [2.0f32; 4];
            accumulate_row_with(level, &[], &[], &x, 4, &mut dst);
            assert_eq!(dst, [2.0; 4]);
            gather_row_with(level, &[], &[], &x, 4, &mut dst);
            assert_eq!(dst, [2.0; 4]);
            accumulate_row_with(level, &[0], &[3.0], &x, 0, &mut []);
            gather_row_with(level, &[0], &[3.0], &x, 0, &mut []);
        }
    }

    #[test]
    fn accumulates_instead_of_overwriting() {
        let f = TILE + 3; // exercise both paths
        let x: Vec<f32> = (0..2 * f).map(|i| i as f32).collect();
        for level in ALL_LEVELS {
            for kernel in [RowKernel::DenseTiled, RowKernel::SparseGather] {
                let mut dst = vec![0f32; f];
                accumulate_row_select(kernel, level, &[0], &[1.0], &x, f, &mut dst);
                accumulate_row_select(kernel, level, &[1], &[1.0], &x, f, &mut dst);
                for k in 0..f {
                    assert_eq!(dst[k], x[k] + x[f + k], "{:?}/{}", kernel, level.name());
                }
            }
        }
    }

    #[test]
    fn prop_matches_naive_random() {
        proptest::check("microkernel_vs_naive", 0x717E, 40, |rng| {
            let f = rng.range(1, 70);
            let n_cols = rng.range(1, 50);
            let x: Vec<f32> = (0..n_cols * f).map(|_| rng.f32() - 0.5).collect();
            let nnz = rng.range(0, 40);
            let cols: Vec<u32> = (0..nnz).map(|_| rng.range(0, n_cols) as u32).collect();
            let vals: Vec<f32> = (0..nnz).map(|_| rng.f32() - 0.5).collect();
            let mut want = vec![0f32; f];
            let mut got = vec![0f32; f];
            naive(&cols, &vals, &x, f, &mut want);
            accumulate_row(&cols, &vals, &x, f, &mut got);
            for (a, b) in got.iter().zip(&want) {
                assert!((a - b).abs() <= 1e-4 * (1.0 + b.abs()), "{a} vs {b}");
            }
        });
    }

    #[test]
    fn selection_rule_thresholds() {
        for deg in 0..=SPARSE_DEG_MAX {
            assert_eq!(select_kernel(deg), RowKernel::SparseGather, "deg {deg}");
        }
        assert_eq!(select_kernel(SPARSE_DEG_MAX + 1), RowKernel::DenseTiled);
        assert_eq!(select_kernel(1000), RowKernel::DenseTiled);
    }

    #[test]
    fn level_metadata_consistent() {
        assert_eq!(SimdLevel::Scalar.name(), "scalar");
        assert_eq!(SimdLevel::Portable.name(), "portable-simd");
        assert!(SimdLevel::Scalar.available() && SimdLevel::Portable.available());
        // effective() never yields an unavailable level
        for level in ALL_LEVELS {
            assert!(level.effective().available(), "{:?}", level);
        }
        // detect() is the widest available level and best() is stable
        assert!(SimdLevel::detect().available());
        assert_eq!(SimdLevel::best(), SimdLevel::best());
        assert!(SimdLevel::best().effective().available());
        // kernel names are distinct (bench/JSON identifiers)
        assert_ne!(RowKernel::DenseTiled.name(), RowKernel::SparseGather.name());
    }

    #[test]
    fn flops_accounting() {
        assert_eq!(spmm_flops(10, 16), 320.0);
        assert_eq!(spmm_flops(0, 64), 0.0);
        assert!((spmm_gflops(1000, 16, 1.0) - 32_000.0 / 1e9).abs() < 1e-15);
        assert!((gflops(2e9, 1.0) - 2.0).abs() < 1e-12);
        // zero wall time is guarded, not infinite
        assert!(gflops(1.0, 0.0).is_finite());
    }
}
