//! Column-tiled SpMM microkernel — the CPU analog of the paper's
//! combined-warp strategy (§III-C).
//!
//! On the GPU, a combined warp's 32 lanes sweep the dense column
//! dimension in lockstep so every global load is coalesced. The CPU
//! translation: walk the columns in fixed-width tiles of [`TILE`]
//! floats, accumulating each tile in a stack array (`[f32; TILE]`) that
//! LLVM keeps in vector registers and autovectorizes — tile width ↔
//! warp span. The nonzero loop iterates `col_idx`/`vals` with a fused
//! `zip`, and the X row slice is reborrowed as a fixed-size `&[f32;
//! TILE]`, so the inner loop carries **no per-element bounds checks**:
//! the compiler sees constant trip counts and in-bounds indices.
//!
//! Columns beyond the last full tile (`f % TILE != 0`) take the ragged
//! tail path: same accumulator array, runtime-bounded lanes. Both paths
//! *accumulate* into `dst` (`+=`), so a destination row can absorb
//! several nonzero ranges (multiple warp tasks of one row, or split-row
//! chunks) in sequence.

/// Column-tile width, in f32 lanes. 16 floats = one 64-byte cache line
/// = two AVX2 / one AVX-512 vector — wide enough to saturate the FMA
/// ports, narrow enough that one accumulator tile always fits the
/// register file.
pub const TILE: usize = 16;

/// `dst[t0 .. t0+TILE] += Σ_i vals[i] · x[cols[i]·f + t0 ..][..TILE]`
/// — one full-width tile, constant trip counts throughout.
#[inline]
fn tile_full(cols: &[u32], vals: &[f32], x: &[f32], f: usize, t0: usize, dst: &mut [f32]) {
    let mut acc = [0f32; TILE];
    for (&c, &v) in cols.iter().zip(vals) {
        let base = c as usize * f + t0;
        let xt: &[f32; TILE] = x[base..base + TILE].try_into().expect("tile in bounds");
        for j in 0..TILE {
            acc[j] += v * xt[j];
        }
    }
    let d: &mut [f32; TILE] = (&mut dst[t0..t0 + TILE]).try_into().expect("tile in bounds");
    for j in 0..TILE {
        d[j] += acc[j];
    }
}

/// The ragged tail: the final `f - t0 < TILE` columns, runtime-bounded
/// lanes over the same stack accumulator.
#[inline]
fn tile_tail(cols: &[u32], vals: &[f32], x: &[f32], f: usize, t0: usize, dst: &mut [f32]) {
    let tw = f - t0;
    debug_assert!(tw > 0 && tw < TILE);
    let mut acc = [0f32; TILE];
    for (&c, &v) in cols.iter().zip(vals) {
        let base = c as usize * f + t0;
        for (a, &xv) in acc[..tw].iter_mut().zip(&x[base..base + tw]) {
            *a += v * xv;
        }
    }
    for (d, a) in dst[t0..].iter_mut().zip(&acc[..tw]) {
        *d += *a;
    }
}

/// Accumulate one sparse row's contribution into its dense output row:
/// `dst[0..f] += Σ_i vals[i] · X[cols[i]]` with `X` row-major
/// `[n_cols × f]`. `cols`/`vals` are the row's (or row chunk's) nonzero
/// slice; `dst` is the full `f`-wide destination row.
#[inline]
pub fn accumulate_row(cols: &[u32], vals: &[f32], x: &[f32], f: usize, dst: &mut [f32]) {
    debug_assert_eq!(cols.len(), vals.len());
    debug_assert_eq!(dst.len(), f);
    if cols.is_empty() || f == 0 {
        return;
    }
    let mut t0 = 0usize;
    while t0 + TILE <= f {
        tile_full(cols, vals, x, f, t0, dst);
        t0 += TILE;
    }
    if t0 < f {
        tile_tail(cols, vals, x, f, t0, dst);
    }
}

/// Floating-point operations of one SpMM: a multiply and an add per
/// (nonzero, column) pair — the GFLOP/s numerator used by the
/// microkernel bench and the serve metrics.
pub fn spmm_flops(nnz: usize, f: usize) -> f64 {
    2.0 * nnz as f64 * f as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest;
    use crate::util::rng::Pcg;

    /// The definitionally-obvious scalar version the tiled kernel must
    /// reproduce (up to f32 addition reordering across tiles — exact
    /// here, since each output lane's sum keeps nonzero order).
    fn naive(cols: &[u32], vals: &[f32], x: &[f32], f: usize, dst: &mut [f32]) {
        for (&c, &v) in cols.iter().zip(vals) {
            for k in 0..f {
                dst[k] += v * x[c as usize * f + k];
            }
        }
    }

    #[test]
    fn matches_naive_across_widths() {
        // full tiles, ragged tails, and sub-tile widths
        for &f in &[1usize, 2, 3, 15, 16, 17, 31, 32, 33, 48, 64, 96, 100, 128] {
            let mut rng = Pcg::seed_from(f as u64 ^ 0xA11);
            let n_cols = 37;
            let x: Vec<f32> = (0..n_cols * f).map(|_| rng.f32() - 0.5).collect();
            let nnz = rng.range(0, 25);
            let cols: Vec<u32> = (0..nnz).map(|_| rng.range(0, n_cols) as u32).collect();
            let vals: Vec<f32> = (0..nnz).map(|_| rng.f32() - 0.5).collect();
            let mut want = vec![0.1f32; f]; // nonzero start: += must preserve it
            let mut got = vec![0.1f32; f];
            naive(&cols, &vals, &x, f, &mut want);
            accumulate_row(&cols, &vals, &x, f, &mut got);
            for (a, b) in got.iter().zip(&want) {
                assert!((a - b).abs() <= 1e-4 * (1.0 + b.abs()), "f={f}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn empty_inputs_are_noops() {
        let x = [1.0f32; 8];
        let mut dst = [2.0f32; 4];
        accumulate_row(&[], &[], &x, 4, &mut dst);
        assert_eq!(dst, [2.0; 4]);
        accumulate_row(&[0], &[3.0], &x, 0, &mut []);
    }

    #[test]
    fn accumulates_instead_of_overwriting() {
        let f = TILE + 3; // exercise both paths
        let x: Vec<f32> = (0..2 * f).map(|i| i as f32).collect();
        let mut dst = vec![0f32; f];
        accumulate_row(&[0], &[1.0], &x, f, &mut dst);
        accumulate_row(&[1], &[1.0], &x, f, &mut dst);
        for k in 0..f {
            assert_eq!(dst[k], x[k] + x[f + k]);
        }
    }

    #[test]
    fn prop_matches_naive_random() {
        proptest::check("microkernel_vs_naive", 0x717E, 40, |rng| {
            let f = rng.range(1, 70);
            let n_cols = rng.range(1, 50);
            let x: Vec<f32> = (0..n_cols * f).map(|_| rng.f32() - 0.5).collect();
            let nnz = rng.range(0, 40);
            let cols: Vec<u32> = (0..nnz).map(|_| rng.range(0, n_cols) as u32).collect();
            let vals: Vec<f32> = (0..nnz).map(|_| rng.f32() - 0.5).collect();
            let mut want = vec![0f32; f];
            let mut got = vec![0f32; f];
            naive(&cols, &vals, &x, f, &mut want);
            accumulate_row(&cols, &vals, &x, f, &mut got);
            for (a, b) in got.iter().zip(&want) {
                assert!((a - b).abs() <= 1e-4 * (1.0 + b.abs()), "{a} vs {b}");
            }
        });
    }

    #[test]
    fn flops_accounting() {
        assert_eq!(spmm_flops(10, 16), 320.0);
        assert_eq!(spmm_flops(0, 64), 0.0);
    }
}
